"""Micro-batching query engine over ``AshIndex`` — the serving layer.

The asymmetric design exists so batched scoring stays one dense
MXU/SIMD-friendly matmul; this engine keeps production traffic on that
path.  Individual (or small-batch) requests are queued, grouped by
search parameters, padded into a small closed set of batch shapes
("buckets") and served by ONE fused scoring call per bucket — so jit
traces are reused across requests instead of re-tracing per novel
request shape, and per-request results are scattered back out
bit-identical to what a direct ``AshIndex.search`` would have returned.

    engine = QueryEngine({"items": index_a, "docs": index_b})
    t1 = engine.submit(q1, k=10, index="items")       # single query
    t2 = engine.submit(q_batch, k=100, index="docs")  # small batch
    engine.flush()                  # or: automatic on size / timeout
    scores, ids = t1.result()
    t1.stats                        # queue wait, bucket, scoring us

Mechanics:

* **Buckets** — pending rows of a group are padded to the smallest
  configured batch bucket (queries pad with zeros, results for pad rows
  are discarded); requested ``k`` is padded to a ``k`` bucket and each
  request takes its first ``k`` columns (top-k prefixes are exact).
  Mixed-``k`` requests therefore share one bucket and one trace — except
  under ``rerank``, where the direct path's shortlist is
  ``max(rerank, k)``: requests group by that size (and the padded ``k``
  is clamped to it) so the fused call reranks the exact same candidate
  set as a per-request call would.
* **Queue** — bounded by ``max_pending`` rows; a group flushes when it
  can fill the largest bucket ("size"), when its oldest request exceeds
  ``max_wait_s`` ("timeout", checked on submit/poll), when a request's
  flush-by deadline arrives ("deadline"), under queue pressure
  ("pressure"), or explicitly ("manual"; frontend shutdown flushes are
  "drain").  Flushes triggered inside ``submit`` never raise — a
  failing fused call resolves every affected ticket with the error,
  re-raised by that ticket's ``result()``.
* **Prep cache** — per-query-row LRU over the QUERY-COMPUTE projections
  (``prepare_queries``): repeated queries skip the projection matmuls
  entirely.  Keyed by (index name, query-row hash); row preps are exact,
  so cache hits stay bit-identical.  Byte-bounded
  (``prep_cache_bytes``; ``prep_cache_entries`` as an optional extra
  row bound), with the live footprint on ``engine.prep_cache_bytes``
  and the hit rate in ``engine.stats.snapshot()``.
* **Registry** — one engine fronts several ``AshIndex`` backends (flat,
  IVF, sharded) for tenant/namespace routing via ``index=``.
* **k > n** — clamped to the index size and padded back out with score
  ``-inf`` / id ``-1`` (the repo-wide missing-candidate convention).
* **Mutations** — ``submit_add`` / ``submit_delete`` queue through the
  same bucket/flush loop as queries.  A mutation submission BARRIERS
  its index: every queued query group for that index flushes first
  (those queries were submitted earlier and must see the pre-mutation
  state), then the mutation stages (adds buffer host-side via
  ``AshIndex.stage_add`` — ids assigned immediately, in submission
  order; deletes queue as id lists).  Staged mutations apply in ONE
  batched step — one IVF re-sort / sharded re-placement per batch —
  before the next query flush of that index, on ``flush()``, on an
  aged ``poll()``, or when the backlog exceeds
  ``max_pending_mutations`` rows; ``auto_compact`` optionally evicts
  tombstones past a dead-fraction threshold right after a batch with
  deletes (synchronously, or off-thread when a
  ``serving.compactor.BackgroundCompactor`` is attached).  Because
  every query flush applies the mutations queued before it, any search
  observes exactly the mutations submitted before it — and results
  stay bit-identical to direct ``AshIndex.search`` on the
  equivalently-mutated index.

Threading model
---------------

The engine core is thread-safe.  The lock discipline has two tiers:

* ``self._lock`` — a global re-entrant lock over the cheap shared
  state: the request queue, mutation bookkeeping, the prep LRU and the
  stats counters.  ``submit``/``submit_add``/``submit_delete`` only
  ever hold this lock (submission is cheap and never blocks behind a
  fused call).
* per-index execution locks (``mutation_barrier(name)``) — ONE fused
  scoring call or mutation apply runs per index at a time.  A flush
  pops its group's requests and releases the global lock before
  scoring, so flushes of *different* indexes run concurrently; two
  threads resolving the same group can never double-run it (the
  second finds the group gone and blocks on the ticket event).  The
  background compactor snapshots and swaps index state under this
  same lock, which is what makes its swap atomic with respect to
  searches and mutation applies.

Lock order is always per-index lock -> global lock; nothing acquires a
per-index lock while holding the global one, so the pair cannot
deadlock.

``Ticket``/``MutationTicket`` are event-backed: ``result(timeout=...)``
blocks on a ``threading.Event`` set exactly once when the batch
resolves.  On an engine without a driver thread, the first ``result()``
caller flushes the group itself (single-threaded serving keeps
working); when a ``serving.frontend.ServingFrontend`` drives the
engine (``engine.driven``), ``result()`` only waits — the driver owns
the flush cadence, so an eager caller cannot defeat batching by
flushing a group early.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import QueryPrep
from repro.index.api import AshIndex, IVFBackend
from repro.serving.cache import ByteLRU
from repro.testing import faults

NEG_INF = float("-inf")

# backends that route coarsely through inverted lists: nprobe grouping,
# the candidate-row cost model and adaptive probing apply to all of
# them (the tiered backend additionally bills paging, see
# _billed_list_sizes)
_IVF_LIKE = ("ivf", "tiered_ivf")

# crash-recovery windows of the mutation apply path: before anything
# durable happened, after the WAL records exist but before the backend
# applied them, and after the apply but before any ticket fired
_FAULT_APPLY = faults.point("engine.apply")
_FAULT_APPLY_LOGGED = faults.point("engine.apply.logged")
_FAULT_APPLY_APPLIED = faults.point("engine.apply.applied")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Tuning knobs of a :class:`QueryEngine`.

    batch_buckets / k_buckets: ascending padded shapes; values above
    the largest bucket round up to a multiple of it (so shapes stay a
    closed set and traces stay bounded).

    The prep cache is BYTE-bounded (``prep_cache_bytes``, summing the
    numpy footprint of every cached row's projection tuple) so capacity
    planning works in memory terms regardless of query width;
    ``prep_cache_entries`` is an optional additional row-count bound
    (None = rows limited by bytes only).  Setting either to 0 disables
    the cache.

    ``row_budget`` / ``nprobe_min`` are the IVF tail-latency knobs.
    ``row_budget`` caps the deduped candidate-row bill (union of live
    rows across the probed lists of every query in a fused call) of
    each IVF sub-batch: groups whose bill exceeds it flush early
    (reason "budget") and split into within-budget sub-batches, so one
    fused gather never serializes an unbounded scan behind every
    ticket in the group.  Both the early flush and the split respect a
    batch-bucket floor — a chunk below the smallest bucket pads back
    up to it, so cutting finer would add dispatches without shrinking
    any gather.  ``nprobe_min`` arms load-adaptive probing:
    under queue pressure (see :meth:`QueryEngine.queue_pressure`)
    flushes walk a halving ladder from the requested nprobe down to
    ``nprobe_min``, trading recall for latency; the trade is surfaced
    in ``snapshot()["ivf_cost"]``.  ``pressure_age_s`` is the
    oldest-ticket age treated as pressure 1.0 (None = 10x
    ``max_wait_s``).  Both knobs default off (None).
    """

    batch_buckets: Tuple[int, ...] = (8, 32, 128)
    k_buckets: Tuple[int, ...] = (10, 100)
    max_pending: int = 1024  # queue bound, in query rows
    max_wait_s: float = 0.002  # flush-on-timeout age
    prep_cache_bytes: int = 64 << 20  # LRU byte budget; 0 disables
    prep_cache_entries: Optional[int] = None  # extra row bound; 0 disables
    # IVF cost model: candidate-row bill cap per fused call (None = off)
    row_budget: Optional[int] = None
    # relative cost of one candidate row under the int8 coarse first
    # pass (groups submitted with coarse="int8"): on integer-MXU
    # hardware the symmetric scan is cheaper per row than the
    # asymmetric estimator, so coarse groups fit more rows under the
    # same row_budget.  1.0 = bill coarse rows at full price — the
    # conservative default, and the right setting on CPU, where both
    # scans are the same-size BLAS GEMM.
    coarse_row_cost: float = 1.0
    # relative cost of one candidate row in a NON-resident inverted
    # list of a tiered index (backend="tiered_ivf"): probing a cold
    # list pays a host->device transfer on top of the scan, so it
    # bills more than a hot row.  Residency is sampled when the bill
    # folds and is advisory — the hot set may shift before the flush.
    page_row_cost: float = 2.0
    # load-adaptive probing floor (None = never degrade nprobe)
    nprobe_min: Optional[int] = None
    # oldest-ticket age mapping to pressure 1.0 (None = 10x max_wait_s)
    pressure_age_s: Optional[float] = None
    # mutation backlog bound, in staged add rows + queued delete ids:
    # past it the batch applies immediately instead of waiting for the
    # next query flush / poll timeout
    max_pending_mutations: int = 4096
    # evict tombstones whenever a mutation batch leaves the index's
    # dead fraction above this (None = never compact automatically);
    # runs synchronously on the applying thread unless a
    # BackgroundCompactor is attached, in which case it only signals
    # the compaction worker
    auto_compact: Optional[float] = None

    def __post_init__(self):
        if not self.batch_buckets or not self.k_buckets:
            raise ValueError("batch_buckets and k_buckets must be non-empty")
        for name in ("batch_buckets", "k_buckets"):
            v = getattr(self, name)
            if tuple(sorted(v)) != tuple(v) or min(v) < 1:
                raise ValueError(f"{name} must be ascending positive: {v}")
        if self.prep_cache_bytes < 0:
            raise ValueError(
                f"prep_cache_bytes must be >= 0: {self.prep_cache_bytes}"
            )
        if self.prep_cache_entries is not None and self.prep_cache_entries < 0:
            raise ValueError(
                f"prep_cache_entries must be >= 0: {self.prep_cache_entries}"
            )
        if self.max_pending_mutations < 1:
            raise ValueError(
                f"max_pending_mutations must be >= 1: "
                f"{self.max_pending_mutations}"
            )
        if self.auto_compact is not None and not (
            0.0 <= self.auto_compact < 1.0
        ):
            raise ValueError(
                f"auto_compact must be in [0, 1): {self.auto_compact}"
            )
        if self.row_budget is not None and self.row_budget < 1:
            raise ValueError(
                f"row_budget must be >= 1: {self.row_budget}"
            )
        if not (0.0 < self.coarse_row_cost <= 1.0):
            raise ValueError(
                f"coarse_row_cost must be in (0, 1]: "
                f"{self.coarse_row_cost}"
            )
        if self.page_row_cost < 1.0:
            raise ValueError(
                f"page_row_cost must be >= 1: {self.page_row_cost}"
            )
        if self.nprobe_min is not None and self.nprobe_min < 1:
            raise ValueError(
                f"nprobe_min must be >= 1: {self.nprobe_min}"
            )
        if self.pressure_age_s is not None and self.pressure_age_s <= 0:
            raise ValueError(
                f"pressure_age_s must be > 0: {self.pressure_age_s}"
            )

    @property
    def prep_cache_enabled(self) -> bool:
        return self.prep_cache_bytes > 0 and self.prep_cache_entries != 0


def _bucketize(buckets: Tuple[int, ...], n: int) -> int:
    """Smallest bucket >= n, else n rounded up to a multiple of the
    largest bucket (keeps the shape set closed for any request size)."""
    for b in buckets:
        if n <= b:
            return b
    big = buckets[-1]
    return ((n + big - 1) // big) * big


def _pad_rows(rows: np.ndarray, bucket: int) -> np.ndarray:
    """Zero-pad (n, D) query rows up to the bucket's row count."""
    if bucket <= rows.shape[0]:
        return rows
    pad = np.zeros((bucket - rows.shape[0], rows.shape[1]), np.float32)
    return np.concatenate([rows, pad], axis=0)


@dataclasses.dataclass
class RequestStats:
    """Per-request serving stats, filled when the request completes."""

    queue_wait_s: float = 0.0  # submit -> scoring start
    latency_s: float = 0.0  # submit -> result scattered back
    batch_rows: int = 0  # real rows in the fused call
    bucket_rows: int = 0  # padded rows (the trace shape)
    scoring_us: float = 0.0  # fused scoring call, whole bucket
    prep_hits: int = 0  # this request's rows found in the prep cache
    prep_misses: int = 0
    # "size" | "budget" (the group's deduped candidate-row bill hit
    # EngineConfig.row_budget) | "timeout" | "deadline" | "manual" |
    # "pressure" | "barrier" (the group was flushed because a mutation
    # arrived for its index) | "drain" (frontend shutdown served the
    # backlog)
    flush_reason: str = ""
    deadline_missed: bool = False  # resolved after its flush-by deadline
    # IVF cost model (0 when off / non-IVF): the nprobe this request's
    # fused call actually probed, and the deduped candidate-row bill of
    # its sub-batch
    effective_nprobe: int = 0
    scanned_rows: int = 0


_FLUSH_REASONS = (
    "size", "budget", "timeout", "deadline", "manual", "pressure",
    "barrier", "drain",
)


@dataclasses.dataclass
class EngineStats:
    """Aggregate counters across the engine lifetime.

    ``snapshot()`` merges the lifetime counters with live gauges
    (current queue depth, oldest queued ticket age) supplied by the
    owning engine, plus the background-compaction counters filled in
    by an attached ``BackgroundCompactor``.
    """

    requests: int = 0
    batches: int = 0  # fused scoring calls
    batched_rows: int = 0  # real rows served
    padded_rows: int = 0  # zero rows added by bucketing
    prep_hits: int = 0
    prep_misses: int = 0
    mutations: int = 0  # submit_add/submit_delete calls
    added_rows: int = 0  # rows ingested via applied mutation batches
    deleted_rows: int = 0  # rows tombstoned via applied batches
    mutation_batches: int = 0  # batched apply steps (the amortized op)
    compactions: int = 0  # synchronous auto_compact evictions
    deadline_missed: int = 0  # requests resolved after their deadline
    queue_hwm: int = 0  # high-water mark of queued query rows
    # background compaction (filled by an attached compactor)
    compact_runs: int = 0  # off-thread survivor builds completed
    compact_retries: int = 0  # rebuilds because mutations landed mid-run
    compact_swap_ms: float = 0.0  # cumulative atomic-swap time
    compact_blocked_ms: float = 0.0  # cumulative wait to acquire the
    # mutation barrier at swap time — serving-path time compaction cost
    # IVF cost model: sub-batches created by the row budget beyond the
    # bucket chunking, fused calls run below the requested nprobe, the
    # cumulative deduped candidate-row bill and the query rows it
    # covered, and a fused-call histogram per effective nprobe (the
    # recall-trade surface: degraded probes show up as mass below the
    # requested nprobe)
    ivf_splits: int = 0
    ivf_degraded: int = 0
    ivf_scanned_rows: int = 0
    ivf_queries: int = 0
    # durability: WAL append failures surfaced by the apply path (the
    # batch is requeued and retried, never silently dropped)
    wal_failures: int = 0
    wal_last_error: Optional[str] = None
    # background-thread supervision (frontend driver / compactor
    # worker): lifetime + consecutive failure counts and the last
    # captured error, so a dying thread is visible in snapshot()
    # instead of silently hanging callers
    driver_failures: int = 0
    driver_consecutive_failures: int = 0
    driver_last_error: Optional[str] = None
    compact_failures: int = 0
    compact_consecutive_failures: int = 0
    compact_last_error: Optional[str] = None
    effective_nprobe: Dict[int, int] = dataclasses.field(
        default_factory=dict
    )
    flushes: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {r: 0 for r in _FLUSH_REASONS}
    )
    # distinct (index, bucket, k, params) combinations that ran — the
    # engine-side upper bound on jit traces of the scoring call
    compiled_buckets: set = dataclasses.field(default_factory=set)
    # zero-arg callable returning live gauges; set by the owning engine
    gauges: Optional[Callable[[], Dict[str, Any]]] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def snapshot(self) -> Dict[str, Any]:
        fill = self.batched_rows / max(
            1, self.batched_rows + self.padded_rows
        )
        looked_up = self.prep_hits + self.prep_misses
        snap = {
            "requests": self.requests,
            "batches": self.batches,
            "rows": self.batched_rows,
            "bucket_fill": round(fill, 3),
            "prep_hits": self.prep_hits,
            "prep_misses": self.prep_misses,
            "prep_hit_rate": round(self.prep_hits / max(1, looked_up), 3),
            "mutations": self.mutations,
            "added_rows": self.added_rows,
            "deleted_rows": self.deleted_rows,
            "mutation_batches": self.mutation_batches,
            "compactions": self.compactions,
            "deadline_missed": self.deadline_missed,
            "queue_hwm": self.queue_hwm,
            "compaction": {
                "runs": self.compact_runs,
                "retries": self.compact_retries,
                "swap_ms": round(self.compact_swap_ms, 3),
                "blocked_ms": round(self.compact_blocked_ms, 3),
            },
            "supervision": {
                "driver_failures": self.driver_failures,
                "driver_consecutive_failures":
                    self.driver_consecutive_failures,
                "driver_last_error": self.driver_last_error,
                "compact_failures": self.compact_failures,
                "compact_consecutive_failures":
                    self.compact_consecutive_failures,
                "compact_last_error": self.compact_last_error,
            },
            "ivf_cost": {
                "splits": self.ivf_splits,
                "degraded": self.ivf_degraded,
                "scanned_rows": self.ivf_scanned_rows,
                "rows_per_query": round(
                    self.ivf_scanned_rows / max(1, self.ivf_queries), 1
                ),
                "effective_nprobe": {
                    str(n): c
                    for n, c in sorted(self.effective_nprobe.items())
                },
            },
            "flushes": dict(self.flushes),
            "unique_buckets": len(self.compiled_buckets),
        }
        if self.gauges is not None:
            snap.update(self.gauges())
        return snap


class _EventTicket:
    """Shared resolution machinery: a one-shot event, the result/error
    slots, and done callbacks (the asyncio bridge).  Resolution happens
    exactly once; late ``add_done_callback`` registrations fire
    immediately on the caller's thread."""

    def __init__(self):
        self._event = threading.Event()
        self._cb_lock = threading.Lock()
        self._callbacks: list = []
        self._result: Optional[Any] = None
        self._error: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def error(self) -> Optional[BaseException]:
        """The resolution error, if the ticket failed (None while
        pending or on success)."""
        return self._error

    def add_done_callback(self, fn) -> None:
        """Run ``fn(self)`` when the ticket resolves (immediately if it
        already has).  Callbacks run on the resolving thread and must
        not block."""
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def _fire(self) -> None:
        with self._cb_lock:
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)

    def _settle(self, result) -> None:
        self._result = result
        self._fire()

    def _fail(self, error: BaseException) -> None:
        if self._event.is_set():  # never overwrite a resolution
            return
        self._error = error
        self._fire()

    def _wait(self, timeout: Optional[float]) -> None:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"ticket unresolved after {timeout}s (is a driver "
                f"thread or flush() serving this engine?)"
            )


class Ticket(_EventTicket):
    """Handle for a submitted request; resolves when its group flushes.

    Event-backed: any number of threads may block in ``result()``
    concurrently — exactly one fused call serves the group, everyone
    wakes on the same event."""

    def __init__(self, engine: "QueryEngine", group: tuple, k: int,
                 n_rows: int, deadline: Optional[float] = None):
        super().__init__()
        self._engine = engine
        self._group = group
        self.k = k
        self.n_rows = n_rows
        self.deadline = deadline  # absolute perf_counter flush-by time
        self.stats = RequestStats()

    def result(
        self, timeout: Optional[float] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(scores, ids), numpy arrays, each (n_rows, k).

        On an undriven engine, flushes the request's group if it is
        still queued (exactly one caller runs the fused call; others
        block on the event).  On a driven engine, blocks until the
        driver's flush cadence resolves the ticket, up to ``timeout``
        seconds (None = forever; raises TimeoutError on expiry).  If
        the fused call for this request's batch failed (e.g. an option
        the backend rejects), re-raises that error here as well as at
        the flush site."""
        if not self.done and not self._engine.driven:
            try:
                self._engine._flush_group(self._group, "manual")
            except Exception:
                pass  # the ticket carries the error; re-raised below
        self._wait(timeout)
        if self._error is not None:
            raise RuntimeError(
                "request failed during its batch's fused scoring call"
            ) from self._error
        assert self._result is not None
        return self._result


class MutationTicket(_EventTicket):
    """Handle for a submitted mutation; resolves when its index's
    queued mutation batch is applied (next query flush of that index,
    ``flush()``, an aged ``poll()``, backlog overflow — or this
    ticket's ``result()`` on an undriven engine)."""

    def __init__(self, engine: "QueryEngine", index_name: str,
                 kind: str, n_rows: int):
        super().__init__()
        self._engine = engine
        self._index = index_name
        self.kind = kind  # "add" | "delete"
        self.n_rows = n_rows  # rows staged (add) / ids requested (delete)
        self.t_enqueue = time.perf_counter()
        self.apply_s = 0.0  # duration of the whole batched apply step
        self.ids: Optional[np.ndarray] = None  # adds: assigned user ids
        # durability: the WAL seqno this mutation was logged under
        # (None until the apply path logs it; stays None without an
        # attached DurableIndex).  _rows retains an add's row block
        # until it is logged, so a WAL record can carry the payload.
        self.wal_seqno: Optional[int] = None
        self._rows: Optional[np.ndarray] = None

    def result(self, timeout: Optional[float] = None):
        """Adds: the (n,) int64 user ids the rows received (also on
        ``.ids`` immediately after submit).  Deletes: the number of
        rows newly tombstoned.  On an undriven engine, applies the
        index's pending mutation batch if it is still queued; on a
        driven engine waits for the driver (up to ``timeout``).
        Re-raises the batch's error if the apply failed."""
        if not self.done and not self._engine.driven:
            try:
                self._engine._apply_mutations(self._index)
            except Exception:
                pass  # the ticket carries the error; re-raised below
        self._wait(timeout)
        if self._error is not None:
            raise RuntimeError(
                "mutation failed during its batched apply step"
            ) from self._error
        return self._result


@dataclasses.dataclass
class _Request:
    queries: np.ndarray  # (m, D) float32, contiguous
    k: int
    ticket: Ticket
    t_enqueue: float
    deadline: Optional[float] = None  # absolute flush-by time
    # IVF cost model: (m, nprobe) host-side coarse assignment,
    # best-first, computed at submit.  Advisory — it drives row
    # accounting (budget trigger + split planning) only; execution
    # recomputes the exact assignment in-jit, so a last-ulp routing
    # difference can never change results
    probe: Optional[np.ndarray] = None


class QueryEngine:
    """See the module docstring.  Thread-safe: any number of threads
    may ``submit``/``result`` concurrently; ``poll``/``flush`` may be
    driven by a serving loop, a ``ServingFrontend`` driver thread, or
    the callers themselves (undriven ``result()`` flushes)."""

    def __init__(
        self,
        indexes: Union[AshIndex, Dict[str, AshIndex], None] = None,
        config: Optional[EngineConfig] = None,
        **overrides,
    ):
        if config is None:
            config = EngineConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self._lock = threading.RLock()
        # signalled whenever queued rows drain (frontend backpressure)
        self._space = threading.Condition(self._lock)
        self._index_locks: Dict[str, threading.RLock] = {}
        self._indexes: Dict[str, AshIndex] = {}
        self._pending: "OrderedDict[tuple, list[_Request]]" = OrderedDict()
        self._pending_rows = 0
        self._prep_cache = ByteLRU(
            config.prep_cache_bytes,
            max_entries=config.prep_cache_entries,
            nbytes_of=self._entry_nbytes,
        )
        # queued mutations, per index: add tickets (rows already staged
        # on the AshIndex), delete id lists, and the oldest submission
        # time (drives the poll() age check)
        self._add_tickets: Dict[str, list] = {}
        self._pending_deletes: Dict[str, list] = {}
        self._mutation_t0: Dict[str, float] = {}
        # IVF cost-model caches: per-index host copies of the coarse
        # quantizer (landmarks^T, 0.5*||mu||^2) and per-mutation-epoch
        # live list sizes
        self._coarse_parts: Dict[str, tuple] = {}
        self._list_sizes: Dict[str, tuple] = {}
        # (name, row digest) -> full best-first list order.  Coarse
        # assignment depends only on the landmarks (fixed per binding;
        # mutations never move them), so repeated queries skip the
        # host matmul+argsort entirely; storing the FULL order makes
        # hits nprobe-independent (a degraded probe reads a prefix)
        self._probe_orders: "OrderedDict[tuple, np.ndarray]" = \
            OrderedDict()
        # per-group running bill: group -> (mutation epoch, probed-list
        # mask, billed live rows).  submit() folds each new probe in
        # incrementally so the budget check stays O(nprobe) per request
        # instead of re-deduping the whole group's probes every time
        self._group_bills: Dict[tuple, tuple] = {}
        # set by ServingFrontend: when True, submit() signals the
        # driver instead of flushing inline and result() only waits
        self.driven = False
        self._on_work: Optional[Callable[[], None]] = None
        # set by BackgroundCompactor.attach(): auto_compact requests
        # route to the worker instead of compacting on this thread
        self._compactor = None
        # per-index DurableIndex (attach_durability): the apply path
        # WAL-logs every mutation batch before its tickets resolve
        self._wals: Dict[str, Any] = {}
        self.stats = EngineStats()
        self.stats.gauges = self._live_gauges
        if isinstance(indexes, AshIndex):
            self.register("default", indexes)
        elif indexes:
            for name, idx in indexes.items():
                self.register(name, idx)

    # -- registry -----------------------------------------------------

    def register(self, name: str, index: AshIndex) -> "QueryEngine":
        """Route ``submit(..., index=name)`` to ``index``.  Re-binding a
        name drops its cached preps (a new index means a new model) and
        first applies any queued mutations against the OLD binding —
        their rows are already staged on that index, so erroring the
        tickets would strand rows that the old index still ingests on
        its next ``apply_pending``.  An apply failure lands on the
        mutation tickets (re-raised by their ``result()``), never here.
        """
        rebind = False
        with self._lock:
            rebind = name in self._indexes
            if name not in self._index_locks:
                self._index_locks[name] = threading.RLock()
        if rebind:
            self._try_flush(self._apply_mutations, name)
            self.invalidate_prep_cache(name)
        with self._lock:
            self._indexes[name] = index
            self._coarse_parts.pop(name, None)
            self._list_sizes.pop(name, None)
            for key in [k for k in self._probe_orders if k[0] == name]:
                del self._probe_orders[key]
            for g in [g for g in self._group_bills if g[0] == name]:
                del self._group_bills[g]
        return self

    def attach_durability(self, durable, *, index: str = "default"):
        """Bind a :class:`~repro.serving.wal.DurableIndex` to ``index``:
        from now on :meth:`_apply_mutations` appends every mutation
        batch to its WAL *before* the batch's tickets resolve, so an
        acknowledged mutation always survives a crash (modulo the
        WAL's fsync policy).  ``durable`` must wrap the registered
        index object — rebinding the name afterwards without a
        matching re-attach is an error the next apply will surface."""
        idx = self._require_index(index)
        if durable.index is not idx:
            raise ValueError(
                f"durable.index is not the index registered as "
                f"{index!r}; attach after register()"
            )
        with self._lock:
            self._wals[index] = durable
        return self

    def durability(self, index: str = "default"):
        """The attached :class:`DurableIndex` of ``index`` (or None)."""
        with self._lock:
            return self._wals.get(index)

    def index(self, name: str = "default") -> AshIndex:
        return self._indexes[name]

    @property
    def index_names(self) -> Tuple[str, ...]:
        return tuple(self._indexes)

    def mutation_barrier(self, name: str = "default") -> threading.RLock:
        """The per-index execution lock: held by every fused scoring
        call and mutation apply of ``name``.  Holding it guarantees no
        search or mutation of that index is in flight — the
        background compactor snapshots and swaps under it, and
        external code may use it the same way (it is re-entrant)."""
        with self._lock:
            lock = self._index_locks.get(name)
            if lock is None:
                lock = self._index_locks[name] = threading.RLock()
            return lock

    def invalidate_prep_cache(self, name: Optional[str] = None) -> None:
        with self._lock:
            if name is None:
                self._prep_cache.clear()
                return
            for key in [k for k in self._prep_cache.keys()
                        if k[0] == name]:
                self._prep_cache.pop(key)

    @property
    def prep_cache_bytes(self) -> int:
        """Current byte footprint of the prep LRU (for capacity
        planning against ``EngineConfig.prep_cache_bytes``)."""
        return self._prep_cache.nbytes

    # -- IVF candidate-row cost model ---------------------------------

    def queue_pressure(self) -> float:
        """Load signal in [0, 1]: the max of queue fill (queued query
        rows vs ``max_pending``) and oldest-ticket age vs the pressure
        horizon (``pressure_age_s``, default 10x ``max_wait_s``) —
        the same gauges ``snapshot()`` reports as ``queue_depth`` /
        ``oldest_ticket_age_s``.  The frontend driver samples it once
        per tick and threads it through ``flush_ready``/``poll``; the
        load-adaptive ladder maps it to an effective nprobe."""
        cfg = self.config
        horizon = cfg.pressure_age_s
        if horizon is None:
            horizon = 10.0 * cfg.max_wait_s
        now = time.perf_counter()
        with self._lock:
            depth = self._pending_rows / max(1, cfg.max_pending)
            oldest = min(
                (reqs[0].t_enqueue for reqs in self._pending.values()
                 if reqs),
                default=None,
            )
        age = (
            0.0 if oldest is None
            else (now - oldest) / max(horizon, 1e-9)
        )
        return float(min(1.0, max(depth, age, 0.0)))

    def _effective_nprobe(self, nprobe: int, pressure: float) -> int:
        """Load-adaptive probing: walk a halving ladder from the
        requested ``nprobe`` down to ``nprobe_min`` as pressure rises.
        Pressure below 1/len(ladder) never degrades (an idle queue
        always serves full fidelity), pressure 1.0 lands on the floor;
        the ladder is a small closed set, so degraded flushes stay on
        a bounded family of jit traces."""
        lo = self.config.nprobe_min
        if lo is None or nprobe <= lo or pressure <= 0.0:
            return nprobe
        ladder = [nprobe]
        while ladder[-1] > lo:
            ladder.append(max(lo, ladder[-1] // 2))
        rung = min(int(min(pressure, 1.0) * len(ladder)),
                   len(ladder) - 1)
        return ladder[rung]

    def _cost_model_on(self, idx: AshIndex, nprobe) -> bool:
        """The cost model engages for partial-probe IVF groups when
        either knob is armed.  nprobe >= nlist runs the dense
        full-scan path — no gather to budget."""
        cfg = self.config
        return (
            idx.backend in _IVF_LIKE
            and nprobe is not None
            and nprobe < idx._state.invlists.shape[0]
            and (cfg.row_budget is not None
                 or cfg.nprobe_min is not None)
        )

    def _host_probe(
        self, name: str, idx: AshIndex, q: np.ndarray, nprobe: int
    ) -> np.ndarray:
        """Approximate coarse assignment, host numpy: (m, nprobe) list
        ids, best-first (so a degraded nprobe reads a column prefix).
        Matches the in-jit routing up to matmul summation order —
        plenty for row accounting, and never touched by execution.
        Single-row probes (the dominant serving shape) are served from
        a per-query LRU of full list orders when the traffic repeats."""
        pkey = None
        if q.shape[0] == 1:
            pkey = (name, hashlib.blake2b(
                q.tobytes(), digest_size=16).digest())
            with self._lock:
                order = self._probe_orders.get(pkey)
                if order is not None:
                    self._probe_orders.move_to_end(pkey)
                    return order[None, :nprobe]
        with self._lock:
            parts = self._coarse_parts.get(name)
        if parts is None:
            st = idx._state
            lm_t = np.ascontiguousarray(
                np.asarray(st.model.landmarks, dtype=np.float32).T
            )
            half = 0.5 * np.asarray(
                st.model.landmark_sq_norms, dtype=np.float32
            )
            parts = (lm_t, half)
            with self._lock:
                self._coarse_parts[name] = parts
        lm_t, half = parts
        coarse = q @ lm_t - half[None, :]
        if pkey is not None:
            # single-row fast path: a full argsort of one nlist-sized
            # row beats partition + gather, and caching the whole
            # order serves any later nprobe as a prefix
            order = np.argsort(-coarse[0], kind="stable").astype(
                np.int32)
            with self._lock:
                self._probe_orders[pkey] = order
                while len(self._probe_orders) > 8192:
                    self._probe_orders.popitem(last=False)
            return order[None, :nprobe]
        if nprobe >= coarse.shape[1]:
            order = np.argsort(-coarse, axis=1, kind="stable")
            return order[:, :nprobe].astype(np.int32)
        part = np.argpartition(-coarse, nprobe - 1, axis=1)[:, :nprobe]
        vals = np.take_along_axis(coarse, part, axis=1)
        order = np.argsort(-vals, axis=1, kind="stable")
        return np.take_along_axis(part, order, axis=1).astype(np.int32)

    def _live_list_sizes(self, name: str, idx: AshIndex) -> np.ndarray:
        """(nlist,) live rows per inverted list — the price of probing
        each list — cached per mutation epoch."""
        epoch = idx.mutation_epoch
        with self._lock:
            cached = self._list_sizes.get(name)
            if cached is not None and cached[0] == epoch:
                return cached[1]
        sizes = idx._backend.list_sizes(idx._state)
        with self._lock:
            self._list_sizes[name] = (epoch, sizes)
        return sizes

    def _billed_list_sizes(
        self, name: str, idx: AshIndex
    ) -> np.ndarray:
        """Per-list row bill: live sizes, with non-resident lists of a
        tiered index surcharged by ``page_row_cost`` (a cold probe
        pays its host->device transfer, so adaptive nprobe and budget
        splitting see paging cost).  Residency is sampled now and may
        shift before the flush — the surcharge is advisory, like the
        host probe itself.  Not epoch-cached: the hot set moves on
        every search, not only on mutations."""
        sizes = self._live_list_sizes(name, idx)
        if idx.backend != "tiered_ivf":
            return sizes
        cost = self.config.page_row_cost
        if cost == 1.0:
            return sizes
        resident = idx._backend.resident_mask(idx._state)
        return np.where(
            resident, sizes, np.ceil(sizes * cost).astype(np.int64)
        )

    def _union_bill(
        self, sizes: np.ndarray, probes: "list[np.ndarray]"
    ) -> int:
        """Deduped candidate-row bill: total live rows across the
        union of the probed lists (a list shared by several queries is
        billed once — correlated traffic batches further under the
        same budget than uncorrelated traffic)."""
        if not probes:
            return 0
        lists = np.unique(np.concatenate([p.ravel() for p in probes]))
        lists = lists[(lists >= 0) & (lists < sizes.size)]
        return int(sizes[lists].sum())

    @staticmethod
    def _fold_bill(
        sizes: np.ndarray, mask: np.ndarray, billed: int,
        probe: np.ndarray,
    ) -> int:
        """Fold one probe into a (mask, billed) accumulator in place:
        bill only the lists not yet marked, mark them.  Equivalent to
        re-running :meth:`_union_bill` over every folded probe."""
        if probe.ndim == 2 and probe.shape[0] == 1:
            # single-row probes (the dominant serving shape) hold
            # distinct lists by construction — skip the sort-dedup
            lists = probe.ravel()
        else:
            lists = np.unique(probe.ravel())
        lists = lists[(lists >= 0) & (lists < sizes.size)]
        fresh = lists[~mask[lists]]
        mask[fresh] = True
        return billed + int(sizes[fresh].sum())

    def _bill_probe(
        self, group: tuple, name: str, idx: AshIndex,
        probe: np.ndarray,
    ) -> None:
        """Account a newly queued probe against the group's cached
        running bill (caller holds the lock; the request is already
        queued).  Fresh cache: one O(nprobe) fold.  Missing or
        epoch-stale cache (first probe, or a mutation changed the
        list sizes): rebuild from everything queued."""
        epoch = idx.mutation_epoch
        sizes = self._billed_list_sizes(name, idx)
        cached = self._group_bills.get(group)
        if cached is not None and cached[0] == epoch:
            _, mask, billed = cached
            billed = self._fold_bill(sizes, mask, billed, probe)
        else:
            mask = np.zeros(sizes.size, dtype=bool)
            billed = 0
            for r in self._pending.get(group, ()):
                if r.probe is not None:
                    billed = self._fold_bill(
                        sizes, mask, billed, r.probe
                    )
        self._group_bills[group] = (epoch, mask, billed)

    def _billed_row_cost(self, group: tuple) -> float:
        """Relative cost of one scanned candidate row for this group:
        1.0 for asymmetric scans, ``coarse_row_cost`` when the group's
        opts opt into the int8 coarse first pass — the budget then
        admits proportionally more rows per fused call."""
        if any(k == "coarse" and v is not None for k, v in group[4]):
            return self.config.coarse_row_cost
        return 1.0

    def _group_over_budget(self, group: tuple) -> bool:
        """Whether the group's queued probes already bill past
        ``row_budget`` (caller holds the lock).  Served from the
        running bill when its mutation epoch is current; otherwise
        re-deduped from the queue.  A group that cannot yet fill the
        smallest batch bucket is never budget-flushed: its fused call
        pads up to that bucket regardless, so flushing early would
        only lower the fill without shrinking the gather."""
        budget = self.config.row_budget
        if budget is None:
            return False
        if self._group_rows(group) < self.config.batch_buckets[0]:
            return False
        name = group[0]
        idx = self._indexes.get(name)
        if idx is None:
            return False
        cost = self._billed_row_cost(group)
        cached = self._group_bills.get(group)
        if cached is not None and cached[0] == idx.mutation_epoch:
            return cached[2] * cost > budget
        reqs = self._pending.get(group, ())
        probes = [r.probe for r in reqs if r.probe is not None]
        if not probes:
            return False
        sizes = self._billed_list_sizes(name, idx)
        return self._union_bill(sizes, probes) * cost > budget

    # -- request intake -----------------------------------------------

    def submit(
        self,
        queries,
        k: int = 10,
        *,
        index: str = "default",
        nprobe: Optional[int] = None,
        rerank: int = 0,
        deadline_s: Optional[float] = None,
        **opts,
    ) -> Ticket:
        """Queue a request; returns a :class:`Ticket`.  Undriven, may
        flush (this group on size, any group on timeout or queue
        pressure); driven, signals the frontend driver instead.

        ``deadline_s`` is a flush-by bound relative to now: the group
        flushes no later than the deadline even if the ``max_wait_s``
        timeout has not aged out, and a request resolved past its
        deadline is counted in ``stats.deadline_missed``."""
        if index not in self._indexes:
            raise KeyError(
                f"unknown index {index!r}; registered: {self.index_names}"
            )
        idx = self._indexes[index]
        q = np.ascontiguousarray(np.asarray(queries), dtype=np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2:
            raise ValueError(f"queries must be (m, D) or (D,): {q.shape}")
        dim = idx.model.landmarks.shape[1]
        if q.shape[1] != dim:
            # reject here: a mismatched row would join the group and
            # blow up mid-flush, taking unrelated requests with it
            raise ValueError(
                f"query dim {q.shape[1]} != index {index!r} dim {dim}"
            )
        if k < 1:
            raise ValueError(f"k must be >= 1: {k}")
        if deadline_s is not None and deadline_s < 0:
            raise ValueError(f"deadline_s must be >= 0: {deadline_s}")
        backend = idx.backend
        if backend not in _IVF_LIKE:
            nprobe = None  # only IVF routes coarsely; don't split groups
        else:
            # normalize to the effective value (default applied, clamped
            # to the invlist count) so nprobe=None, the explicit default
            # and any over-large value share one group/bucket/trace
            nprobe = idx._backend.resolve_nprobe(idx._state, nprobe)
        # rerank requests must reproduce the direct path's shortlist of
        # max(rerank, k) candidates, so that size is part of the group
        # key and _run_batch clamps k_run to it.  Requests with
        # rerank >= k all share one group (shortlist == rerank); a
        # request with rerank < k gets its own (shortlist == its k) —
        # mixed-k groups there cannot share a fused call bit-identically.
        shortlist = max(rerank, k) if rerank else None
        group = (index, nprobe, rerank, shortlist,
                 tuple(sorted(opts.items())))

        driven = self.driven
        if not driven:
            # bounded queue: free space by serving, never by dropping
            with self._lock:
                pressured = (
                    self._pending_rows + q.shape[0] > self.config.max_pending
                    and self._pending_rows > 0
                )
            if pressured:
                self._try_flush(self._flush_all, "pressure")

        probe = None
        if self._cost_model_on(idx, nprobe):
            probe = self._host_probe(index, idx, q, nprobe)

        now = time.perf_counter()
        deadline = None if deadline_s is None else now + deadline_s
        ticket = Ticket(self, group, k, q.shape[0], deadline)
        with self._lock:
            self._pending.setdefault(group, []).append(
                _Request(q, k, ticket, now, deadline, probe)
            )
            if probe is not None:
                self._bill_probe(group, index, idx, probe)
            self._pending_rows += q.shape[0]
            self.stats.requests += 1
            self.stats.queue_hwm = max(
                self.stats.queue_hwm, self._pending_rows
            )
            group_full = (
                self._group_rows(group) >= self.config.batch_buckets[-1]
            )
            over_bound = self._pending_rows > self.config.max_pending
            # cost model: a group whose deduped candidate-row bill
            # already exceeds the budget gains nothing by waiting for
            # the bucket to fill — every extra query only deepens the
            # serialized gather behind all its tickets
            budget_full = (
                not group_full
                and probe is not None
                and self._group_over_budget(group)
            )

        if driven:
            # wake the driver only when this submit made something
            # flushable — a fillable bucket, an over-budget bill, or
            # queue pressure.  Sub-bucket groups ride the driver's
            # poll tick instead (bounded by poll_interval_s), so a
            # burst of submits costs one driver scan, not one per row
            if group_full or budget_full or over_bound:
                self._notify_work()
        elif group_full or over_bound:
            # bucket fillable, or a single request alone exceeds the
            # queue bound: serve now rather than sit past max_pending
            self._try_flush(self._flush_group, group, "size")
        elif budget_full:
            self._try_flush(self._flush_group, group, "budget")
        else:
            self._try_flush(self.poll)
        return ticket

    def search(self, queries, k: int = 10, **kw):
        """Synchronous convenience: submit + resolve immediately.
        (scores, ids) numpy arrays, each (m, k)."""
        return self.submit(queries, k, **kw).result()

    # -- mutation intake ----------------------------------------------

    def submit_add(self, rows, *, index: str = "default") -> MutationTicket:
        """Queue rows for batched ingestion; returns a
        :class:`MutationTicket` whose ``.ids`` already holds the user
        ids the rows will carry (assigned now, in submission order).

        Barriers the index first: queued query groups for it flush
        (they were submitted before this mutation and must see the
        pre-mutation state).  The rows stage host-side and the
        expensive apply (one IVF re-sort / sharded re-placement for
        the WHOLE batch) is deferred to the next query flush of this
        index, ``flush()``, an aged ``poll()``, or backlog overflow.
        """
        idx = self._require_index(index)
        q = np.ascontiguousarray(np.asarray(rows), dtype=np.float32)
        if q.ndim == 1:
            q = q[None, :]
        dim = idx.model.landmarks.shape[1]
        if q.ndim != 2 or q.shape[1] != dim:
            raise ValueError(
                f"add rows must be (n, {dim}) for index {index!r}: "
                f"got {q.shape}"
            )
        self._barrier(index)
        ticket = MutationTicket(self, index, "add", q.shape[0])
        with self.mutation_barrier(index):
            # staging mutates index state: serialize against in-flight
            # applies so id assignment stays in submission order
            ticket.ids = idx.stage_add(q)
            ticket._rows = q  # retained until the apply path logs it
            with self._lock:
                self._add_tickets.setdefault(index, []).append(ticket)
                self._mutation_t0.setdefault(index, ticket.t_enqueue)
                self.stats.mutations += 1
        self._maybe_apply(index)
        if self.driven:
            self._notify_work()
        return ticket

    def submit_delete(self, ids, *, index: str = "default") -> MutationTicket:
        """Queue a tombstone delete by user id; the ticket resolves to
        the number of rows newly removed (unknown / already-deleted
        ids are ignored).  Same barrier/batching semantics as
        :meth:`submit_add`; deletes never pay a re-sort at all — only
        an eventual ``compact()`` does."""
        self._require_index(index)
        del_ids = np.asarray(ids).reshape(-1).astype(np.int64)
        self._barrier(index)
        ticket = MutationTicket(self, index, "delete", int(del_ids.size))
        with self._lock:
            self._pending_deletes.setdefault(index, []).append(
                (del_ids, ticket)
            )
            self._mutation_t0.setdefault(index, ticket.t_enqueue)
            self.stats.mutations += 1
        self._maybe_apply(index)
        if self.driven:
            self._notify_work()
        return ticket

    def _require_index(self, index: str) -> AshIndex:
        if index not in self._indexes:
            raise KeyError(
                f"unknown index {index!r}; registered: {self.index_names}"
            )
        return self._indexes[index]

    def _barrier(self, name: str) -> None:
        """Flush every queued query group of ``name`` (reason
        "barrier") so queries submitted before a mutation never see
        post-mutation state.  Errors stay on the affected query
        tickets, exactly like submit-triggered flushes."""
        with self._lock:
            groups = [g for g in self._pending if g[0] == name]
        for group in groups:
            self._try_flush(self._flush_group, group, "barrier")

    def _mutation_backlog(self, name: str) -> int:
        return self._indexes[name].pending_rows + sum(
            int(d.size) for d, _ in self._pending_deletes.get(name, ())
        )

    def _maybe_apply(self, name: str) -> None:
        with self._lock:
            over = (
                self._mutation_backlog(name)
                >= self.config.max_pending_mutations
            )
        if over:
            self._try_flush(self._apply_mutations, name)

    def _apply_mutations(self, name: str) -> int:
        """Apply the index's queued mutation batch: WAL-log every
        queued mutation (when durability is attached — the batch is
        requeued intact if logging fails, so no acknowledged-but-
        unlogged state can exist), then ONE backend add for every
        staged row, then the queued deletes (order-equivalent to FIFO
        — delete targets are ids, which adds never disturb), then an
        optional auto-compaction.  Tickets fire only after their
        records are in the log.  Returns rows added + removed."""
        with self.mutation_barrier(name):
            with self._lock:
                idx = self._indexes.get(name)
                if idx is None:
                    return 0
                has_work = bool(
                    self._add_tickets.get(name)
                    or self._pending_deletes.get(name)
                    or idx.pending_rows
                )
            if not has_work:
                return 0
            # fired before the batch leaves the queues: a failure here
            # (crash or transient error) leaves everything queued for a
            # clean retry
            faults.fire(_FAULT_APPLY)
            with self._lock:
                adds = self._add_tickets.pop(name, [])
                dels = self._pending_deletes.pop(name, [])
                self._mutation_t0.pop(name, None)
                wal = self._wals.get(name)
            if not adds and not dels and idx.pending_rows == 0:
                return 0
            if wal is not None and (adds or dels):
                try:
                    # submission order: adds before deletes, matching
                    # the apply below — replay is order-faithful.  A
                    # ticket logged by an earlier, failed apply keeps
                    # its seqno (idempotent retry, no double record).
                    for ticket in adds:
                        if ticket.wal_seqno is None:
                            ticket.wal_seqno = wal.log_add(
                                ticket._rows, ticket.ids
                            )
                        ticket._rows = None
                    for del_ids, ticket in dels:
                        if ticket.wal_seqno is None:
                            ticket.wal_seqno = wal.log_delete(del_ids)
                except Exception as e:
                    # logging failed (disk full, ...): requeue the
                    # whole batch for a later retry — tickets stay
                    # unresolved rather than acknowledging work the
                    # log does not hold
                    with self._lock:
                        self._add_tickets[name] = (
                            adds + self._add_tickets.get(name, [])
                        )
                        self._pending_deletes[name] = (
                            dels + self._pending_deletes.get(name, [])
                        )
                        pending = (
                            adds + [t for _, t in dels]
                        )
                        self._mutation_t0[name] = min(
                            t.t_enqueue for t in pending
                        )
                        self.stats.wal_failures += 1
                        self.stats.wal_last_error = repr(e)
                    raise
            faults.fire(_FAULT_APPLY_LOGGED)
            t0 = time.perf_counter()
            try:
                applied = idx.apply_pending()
                removed = 0
                for del_ids, ticket in dels:
                    removed_now = idx.delete(del_ids)
                    ticket._result = removed_now
                    removed += removed_now
            except Exception as e:
                for ticket in adds + [t for _, t in dels]:
                    ticket._fail(e)
                raise
            faults.fire(_FAULT_APPLY_APPLIED)
            if (
                dels
                and self.config.auto_compact is not None
                and idx.dead_fraction > self.config.auto_compact
            ):
                if self._compactor is not None:
                    # compaction cost leaves the serving path: the
                    # worker builds survivor arrays off-thread and
                    # swaps them in between flushes
                    self._compactor.request(name)
                else:
                    n_before = idx.n
                    idx.compact(self.config.auto_compact)
                    if idx.n != n_before:
                        with self._lock:
                            self.stats.compactions += 1
                        if wal is not None:
                            wal.log_marker("compact")
            dt = time.perf_counter() - t0
            for ticket in adds:
                ticket._result = ticket.ids
            for ticket in adds + [t for _, t in dels]:
                ticket.apply_s = dt
                ticket._fire()
            with self._lock:
                self.stats.mutation_batches += 1
                self.stats.added_rows += applied
                self.stats.deleted_rows += removed
            return applied + removed

    # -- flushing -----------------------------------------------------

    def poll(self, pressure: Optional[float] = None) -> int:
        """Flush groups whose oldest request exceeded ``max_wait_s``
        ("timeout") or whose earliest flush-by deadline arrived
        ("deadline"), and apply mutation batches older than
        ``max_wait_s``.  Call this from the serving loop's idle path
        (the ``ServingFrontend`` driver calls it on every tick,
        passing its per-tick ``queue_pressure()`` sample so
        load-adaptive probing sees the pre-flush backlog).  Returns
        the number of requests completed (mutations resolve their own
        tickets)."""
        now = time.perf_counter()
        due = []
        with self._lock:
            for group, reqs in self._pending.items():
                if not reqs:
                    continue
                if now - reqs[0].t_enqueue >= self.config.max_wait_s:
                    due.append((group, "timeout"))
                    continue
                deadlines = [
                    r.deadline for r in reqs if r.deadline is not None
                ]
                if deadlines and now >= min(deadlines):
                    due.append((group, "deadline"))
            aged = [
                nm for nm, t0 in self._mutation_t0.items()
                if now - t0 >= self.config.max_wait_s
            ]
        done = 0
        for group, reason in due:
            done += self._flush_group(group, reason, pressure)
        for name in aged:
            self._apply_mutations(name)
        return done

    def flush_ready(self, pressure: Optional[float] = None) -> int:
        """Driver-facing size/budget/pressure cadence: flush every
        group that can fill the largest bucket ("size") or whose
        deduped candidate-row bill exceeds ``row_budget`` ("budget"),
        and — as a safety net if the queue bound is exceeded —
        everything ("pressure").  Returns requests completed."""
        with self._lock:
            big = self.config.batch_buckets[-1]
            ready = [
                (g, "size") for g in self._pending
                if self._group_rows(g) >= big
            ]
            if self.config.row_budget is not None:
                seen = {g for g, _ in ready}
                ready += [
                    (g, "budget") for g in self._pending
                    if g not in seen and self._group_over_budget(g)
                ]
            pressured = self._pending_rows > self.config.max_pending
        done = 0
        for group, reason in ready:
            done += self._flush_group(group, reason, pressure)
        if pressured:
            done += self._flush_all("pressure", pressure)
        return done

    def flush(self) -> int:
        """Serve everything queued, now — query groups AND mutation
        batches.  Returns requests completed; an empty flush is a
        no-op returning 0."""
        return self._drain("manual")

    def drain(self) -> int:
        """Like :meth:`flush` but tagged "drain" in the flush-reason
        telemetry — the frontend's shutdown path."""
        return self._drain("drain")

    def _drain(self, reason: str) -> int:
        done = self._flush_all(reason)
        with self._lock:
            names = list(self._mutation_t0)
        for name in names:
            self._apply_mutations(name)
        return done

    def _flush_all(
        self, reason: str, pressure: Optional[float] = None
    ) -> int:
        done = 0
        with self._lock:
            groups = list(self._pending)
        for group in groups:
            done += self._flush_group(group, reason, pressure)
        return done

    @staticmethod
    def _try_flush(fn, *args) -> None:
        """Run a flush triggered from inside ``submit`` without letting
        its errors escape: the caller must always receive its Ticket,
        and a failing fused call (possibly an unrelated group's) already
        resolved every affected ticket with the error — delivered when
        that ticket's ``result()`` is called."""
        try:
            fn(*args)
        except Exception:
            pass

    @property
    def pending_requests(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._pending.values())

    @property
    def pending_rows(self) -> int:
        """Queued query rows (the ``max_pending`` bound applies to
        this; the frontend's backpressure gate watches it)."""
        return self._pending_rows

    def _group_rows(self, group: tuple) -> int:
        return sum(
            r.queries.shape[0] for r in self._pending.get(group, ())
        )

    def _live_gauges(self) -> Dict[str, Any]:
        """Live queue gauges merged into ``stats.snapshot()``."""
        now = time.perf_counter()
        cfg = self.config
        horizon = cfg.pressure_age_s
        if horizon is None:
            horizon = 10.0 * cfg.max_wait_s
        with self._lock:
            oldest = min(
                (r.t_enqueue for reqs in self._pending.values()
                 for r in reqs),
                default=None,
            )
            age = 0.0 if oldest is None else now - oldest
            pressure = min(1.0, max(
                self._pending_rows / max(1, cfg.max_pending),
                age / max(horizon, 1e-9),
            ))
            gauges = {
                "queue_depth": self._pending_rows,
                "oldest_ticket_age_s": (
                    0.0 if oldest is None else round(age, 6)
                ),
                "queue_pressure": round(pressure, 4),
                "durability": {
                    "wal_failures": self.stats.wal_failures,
                    "wal_last_error": self.stats.wal_last_error,
                    "indexes": {
                        nm: d.stats() for nm, d in self._wals.items()
                    },
                },
            }
            tier = {
                nm: ix._backend.tier_stats(ix._state)
                for nm, ix in self._indexes.items()
                if ix.backend == "tiered_ivf"
            }
            if tier:
                gauges["tier"] = tier
            return gauges

    def _notify_work(self) -> None:
        cb = self._on_work
        if cb is not None:
            cb()

    def _abort_pending(self, exc: BaseException) -> int:
        """Fail every queued query ticket with ``exc`` (frontend
        ``stop(drain=False)``).  Mutation batches are APPLIED, not
        failed — their rows are already staged on the index, so
        failing the tickets would strand state the index ingests on
        its next apply anyway."""
        with self._lock:
            names = list(self._mutation_t0)
        for name in names:
            self._try_flush(self._apply_mutations, name)
        with self._lock:
            popped = list(self._pending.items())
            self._pending.clear()
            self._group_bills.clear()
            self._pending_rows = 0
            self._space.notify_all()
        n = 0
        for _, reqs in popped:
            for r in reqs:
                r.ticket._fail(exc)
                n += 1
        return n

    def _flush_group(
        self, group: tuple, reason: str,
        pressure: Optional[float] = None,
    ) -> int:
        name = group[0]
        if pressure is None and self.config.nprobe_min is not None:
            # undriven flush with adaptive probing armed: sample the
            # backlog before popping this group out of it
            pressure = self.queue_pressure()
        with self.mutation_barrier(name):
            with self._lock:
                queued = group in self._pending
            if queued:
                # every queued query of this index was submitted AFTER
                # the mutations still pending for it (each mutation
                # submission barrier-flushed the older queries before
                # staging), so applying the backlog here makes the
                # batch observe exactly the mutations submitted before
                # it — including during a barrier flush, where the
                # NEWEST mutation is not queued yet and therefore
                # (correctly) not applied.
                self._apply_mutations(name)
            with self._lock:
                reqs = self._pending.pop(group, None)
                self._group_bills.pop(group, None)
                if not reqs:
                    return 0
                self._pending_rows -= sum(
                    r.queries.shape[0] for r in reqs
                )
                self.stats.flushes[reason] += 1
                self._space.notify_all()  # queue rows freed
            eff_nprobe, chunks, bills = self._plan_chunks(
                group, reqs, pressure
            )
            for i, chunk in enumerate(chunks):
                try:
                    self._run_batch(
                        group, chunk, reason,
                        eff_nprobe=eff_nprobe, billed=bills[i],
                    )
                except Exception as e:
                    # the failed chunk's tickets carry the error
                    # already (_run_batch); later chunks were popped
                    # off the queue too, so resolve them with it as
                    # well — no request may end up neither served nor
                    # errored
                    for later in chunks[i + 1:]:
                        for r in later:
                            r.ticket._fail(e)
                    raise
            return len(reqs)

    def _plan_chunks(
        self,
        group: tuple,
        reqs: "list[_Request]",
        pressure: Optional[float],
    ) -> Tuple[Optional[int], "list[list[_Request]]", "list[int]"]:
        """Sub-batch a popped group for execution.

        Always: FIFO chunks bounded by the largest bucket (a single
        oversized request still rides alone, padded to a multiple).
        IVF cost model: each chunk's deduped candidate-row bill (union
        of live rows across its queries' probed lists) additionally
        stays within ``row_budget`` — queries sharing lists batch
        together cheaply, disjoint ones split — and under queue
        pressure the whole flush degrades to the ladder's effective
        nprobe (billed on the probe column prefix).  A budget split
        never cuts a chunk below the smallest bucket: such a chunk
        pads back up to that bucket anyway, so the split would add a
        dispatch without shrinking any gather.  The budget's bite is
        keeping a backlogged group off the big bucket — one
        serialized monster gather becomes several small-bucket calls.
        Returns (effective nprobe or None, chunks, per-chunk bills).
        """
        name, nprobe, _, _, _ = group
        big = self.config.batch_buckets[-1]
        small = self.config.batch_buckets[0]
        probes = [r.probe for r in reqs]
        costed = nprobe is not None and all(
            p is not None for p in probes
        )
        eff = nprobe
        budget = None
        sizes = None
        if costed:
            if self.config.nprobe_min is not None:
                eff = self._effective_nprobe(
                    nprobe, pressure if pressure is not None else 0.0
                )
            budget = self.config.row_budget
            idx = self._indexes.get(name)
            costed = idx is not None
            if costed:
                sizes = self._billed_list_sizes(name, idx)
        row_cost = self._billed_row_cost(group)

        chunks: "list[list[_Request]]" = [[]]
        bills: "list[int]" = [0]
        rows = 0
        # running union of the current chunk's probed lists, folded
        # incrementally (one O(nprobe) mask probe per request, not a
        # re-dedup of the whole chunk per request)
        mask = np.zeros(sizes.size, dtype=bool) if costed else None
        splits = 0
        for r in reqs:
            m = r.queries.shape[0]
            lists = None
            if costed and r.probe is not None:
                p = r.probe[:, :eff] if eff < r.probe.shape[1] \
                    else r.probe
                lists = np.unique(p.ravel())
                lists = lists[(lists >= 0) & (lists < sizes.size)]
            over_rows = bool(chunks[-1]) and rows + m > big
            over_budget = False
            if not over_rows and lists is not None \
                    and budget is not None and chunks[-1] \
                    and rows >= small:
                fresh = lists[~mask[lists]]
                over_budget = (
                    (bills[-1] + int(sizes[fresh].sum())) * row_cost
                    > budget
                )
            if over_rows or over_budget:
                if over_budget:
                    splits += 1
                chunks.append([])
                bills.append(0)
                rows = 0
                if mask is not None:
                    mask[:] = False
            chunks[-1].append(r)
            rows += m
            if lists is not None:
                fresh = lists[~mask[lists]]
                mask[fresh] = True
                bills[-1] += int(sizes[fresh].sum())

        if costed:
            with self._lock:
                self.stats.ivf_splits += splits
                self.stats.ivf_scanned_rows += sum(bills)
                self.stats.ivf_queries += sum(
                    r.queries.shape[0] for r in reqs
                )
                self.stats.effective_nprobe[eff] = (
                    self.stats.effective_nprobe.get(eff, 0)
                    + len(chunks)
                )
                if eff < nprobe:
                    self.stats.ivf_degraded += len(chunks)
        return (eff if costed else nprobe), chunks, bills

    # -- the fused scoring call ---------------------------------------

    def _run_batch(
        self, group: tuple, reqs: "list[_Request]", reason: str,
        *, eff_nprobe: Optional[int] = None, billed: int = 0,
    ) -> None:
        name, nprobe, rerank, shortlist, opts = group
        if eff_nprobe is not None:
            # cost model / load-adaptive probing: the flush planner may
            # have degraded nprobe below the group's requested value
            nprobe = eff_nprobe
        idx = self._indexes[name]
        try:
            rows = np.concatenate([r.queries for r in reqs], axis=0)
            n_real = rows.shape[0]
            bucket = _bucketize(self.config.batch_buckets, n_real)
            rows = _pad_rows(rows, bucket)
            k_max = max(r.k for r in reqs)
            k_run = min(
                _bucketize(self.config.k_buckets, k_max), idx.n
            )
            if shortlist is not None:
                # rerank: the backend's shortlist is max(rerank, k_run);
                # the direct path's is max(rerank, k).  Every request in
                # this group shares shortlist == max(rerank, its k)
                # >= k_max (the group key guarantees it), so clamping
                # k_run keeps the fused call's shortlist — hence its
                # rerank candidates and results — bit-identical to
                # per-request search.
                k_run = min(k_run, shortlist)

            prep, hit_rows = self._prep_for(name, idx, rows, n_real)
            t_score = time.perf_counter()  # after prep/hash: the stat
            scores, ids = jax.block_until_ready(  # is the fused call
                idx.search_prepped(
                    prep, k=k_run, nprobe=nprobe, rerank=rerank,
                    **dict(opts),
                )
            )
        except Exception as e:
            # resolve every ticket with the error (a later result()
            # re-raises it) before surfacing at the flush site — an
            # explicit flush()/poll(); submit-triggered flushes swallow
            # it (_try_flush) so the caller still gets its Ticket
            for r in reqs:
                r.ticket._fail(e)
            raise
        scoring_us = (time.perf_counter() - t_score) * 1e6
        scores = np.asarray(scores)
        ids = np.asarray(ids)

        with self._lock:
            self.stats.batches += 1
            self.stats.batched_rows += n_real
            self.stats.padded_rows += bucket - n_real
            self.stats.compiled_buckets.add(
                (name, idx.backend, bucket, k_run, nprobe, rerank, opts)
            )

        offset = 0
        missed = 0
        for r in reqs:
            m = r.queries.shape[0]
            s = scores[offset:offset + m]
            i = ids[offset:offset + m]
            if r.k <= k_run:  # top-k prefix of the bucket's top-k_run
                s, i = s[:, : r.k], i[:, : r.k]
            else:  # k > n: pad out with the missing-candidate sentinel
                pad = r.k - k_run
                s = np.concatenate(
                    [s, np.full((m, pad), NEG_INF, s.dtype)], axis=1
                )
                i = np.concatenate(
                    [i, np.full((m, pad), -1, i.dtype)], axis=1
                )
            now = time.perf_counter()
            st = r.ticket.stats
            st.queue_wait_s = t_score - r.t_enqueue
            st.latency_s = now - r.t_enqueue
            st.batch_rows = n_real
            st.bucket_rows = bucket
            st.scoring_us = scoring_us
            st.prep_hits = int(hit_rows[offset:offset + m].sum())
            st.prep_misses = m - st.prep_hits
            st.flush_reason = reason
            if r.probe is not None and nprobe is not None:
                st.effective_nprobe = nprobe
                st.scanned_rows = billed
            if r.deadline is not None and now > r.deadline:
                st.deadline_missed = True
                missed += 1
            r.ticket._settle((s, i))
            offset += m
        if missed:
            with self._lock:
                self.stats.deadline_missed += missed

    # -- prep cache ---------------------------------------------------

    def _prep_for(
        self, name: str, idx: AshIndex, rows: np.ndarray, n_real: int
    ) -> Tuple[QueryPrep, np.ndarray]:
        """QueryPrep for the padded bucket ``rows``, reusing cached
        per-row projections.  Returns (prep, per-row hit flags for the
        real rows)."""
        bucket = rows.shape[0]
        hit_rows = np.zeros(n_real, dtype=bool)
        if not self.config.prep_cache_enabled:
            with self._lock:
                self.stats.prep_misses += n_real
            return idx.prepare(jnp.asarray(rows)), hit_rows

        keys = [
            (name, hashlib.blake2b(rows[i].tobytes(),
                                   digest_size=16).digest())
            for i in range(bucket)
        ]
        row_preps: list = [None] * bucket
        miss = []
        with self._lock:
            for i, key in enumerate(keys):
                cached = self._prep_cache.get(key)
                if cached is not None:
                    row_preps[i] = cached
                    if i < n_real:
                        hit_rows[i] = True
                else:
                    miss.append(i)
            self.stats.prep_hits += int(hit_rows.sum())
            self.stats.prep_misses += n_real - int(hit_rows.sum())

        if not miss:
            return self._stack_prep(row_preps), hit_rows
        if len(miss) == bucket:
            # cold bucket: one prepare over the padded rows, no restack
            # (only real rows are cached — pad rows recur only while
            # buckets run underfilled and would waste LRU capacity)
            prep = jax.block_until_ready(idx.prepare(jnp.asarray(rows)))
            self._cache_prep_rows(keys, prep, range(n_real))
            return prep, hit_rows
        # warm bucket: prepare only the misses (padded to a bucket shape
        # so prepare traces stay bounded), then merge with cached rows
        mb = _bucketize(self.config.batch_buckets, len(miss))
        miss_rows = _pad_rows(rows[miss], mb)
        mp = jax.block_until_ready(idx.prepare(jnp.asarray(miss_rows)))
        mp_np = tuple(np.asarray(a) for a in
                      (mp.q, mp.q_proj, mp.ip_q_landmarks, mp.q_sq_norm))
        for j, i in enumerate(miss):
            row_preps[i] = tuple(a[j] for a in mp_np)
        with self._lock:
            for i in miss:
                if i < n_real:
                    self._prep_cache.put(keys[i], row_preps[i])
        return self._stack_prep(row_preps), hit_rows

    def _cache_prep_rows(self, keys, prep: QueryPrep, idxs) -> None:
        arrs = tuple(np.asarray(a) for a in
                     (prep.q, prep.q_proj, prep.ip_q_landmarks,
                      prep.q_sq_norm))
        with self._lock:
            for i in idxs:
                self._prep_cache.put(keys[i], tuple(a[i] for a in arrs))

    @staticmethod
    def _entry_nbytes(entry: tuple) -> int:
        return sum(int(a.nbytes) for a in entry)

    @staticmethod
    def _stack_prep(row_preps) -> QueryPrep:
        # stack on host, then one device_put for all four fields — four
        # separate jnp.asarray dispatches dominate small-bucket flushes
        q, q_proj, ipl, qsq = jax.device_put(tuple(
            np.stack([r[f] for r in row_preps]) for f in range(4)
        ))
        return QueryPrep(
            q=q, q_proj=q_proj, ip_q_landmarks=ipl, q_sq_norm=qsq
        )
