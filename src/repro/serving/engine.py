"""Micro-batching query engine over ``AshIndex`` — the serving layer.

The asymmetric design exists so batched scoring stays one dense
MXU/SIMD-friendly matmul; this engine keeps production traffic on that
path.  Individual (or small-batch) requests are queued, grouped by
search parameters, padded into a small closed set of batch shapes
("buckets") and served by ONE fused scoring call per bucket — so jit
traces are reused across requests instead of re-tracing per novel
request shape, and per-request results are scattered back out
bit-identical to what a direct ``AshIndex.search`` would have returned.

    engine = QueryEngine({"items": index_a, "docs": index_b})
    t1 = engine.submit(q1, k=10, index="items")       # single query
    t2 = engine.submit(q_batch, k=100, index="docs")  # small batch
    engine.flush()                  # or: automatic on size / timeout
    scores, ids = t1.result()
    t1.stats                        # queue wait, bucket, scoring us

Mechanics:

* **Buckets** — pending rows of a group are padded to the smallest
  configured batch bucket (queries pad with zeros, results for pad rows
  are discarded); requested ``k`` is padded to a ``k`` bucket and each
  request takes its first ``k`` columns (top-k prefixes are exact).
  Mixed-``k`` requests therefore share one bucket and one trace — except
  under ``rerank``, where the direct path's shortlist is
  ``max(rerank, k)``: requests group by that size (and the padded ``k``
  is clamped to it) so the fused call reranks the exact same candidate
  set as a per-request call would.
* **Queue** — bounded by ``max_pending`` rows; a group flushes when it
  can fill the largest bucket ("size"), when its oldest request exceeds
  ``max_wait_s`` ("timeout", checked on submit/poll), under queue
  pressure ("pressure"), or explicitly ("manual").  Flushes triggered
  inside ``submit`` never raise — a failing fused call resolves every
  affected ticket with the error, re-raised by that ticket's
  ``result()``.
* **Prep cache** — per-query-row LRU over the QUERY-COMPUTE projections
  (``prepare_queries``): repeated queries skip the projection matmuls
  entirely.  Keyed by (index name, query-row hash); row preps are exact,
  so cache hits stay bit-identical.  Byte-bounded
  (``prep_cache_bytes``; ``prep_cache_entries`` as an optional extra
  row bound), with the live footprint on ``engine.prep_cache_bytes``
  and the hit rate in ``engine.stats.snapshot()``.
* **Registry** — one engine fronts several ``AshIndex`` backends (flat,
  IVF, sharded) for tenant/namespace routing via ``index=``.
* **k > n** — clamped to the index size and padded back out with score
  ``-inf`` / id ``-1`` (the repo-wide missing-candidate convention).
* **Mutations** — ``submit_add`` / ``submit_delete`` queue through the
  same bucket/flush loop as queries.  A mutation submission BARRIERS
  its index: every queued query group for that index flushes first
  (those queries were submitted earlier and must see the pre-mutation
  state), then the mutation stages (adds buffer host-side via
  ``AshIndex.stage_add`` — ids assigned immediately, in submission
  order; deletes queue as id lists).  Staged mutations apply in ONE
  batched step — one IVF re-sort / sharded re-placement per batch —
  before the next query flush of that index, on ``flush()``, on an
  aged ``poll()``, or when the backlog exceeds
  ``max_pending_mutations`` rows; ``auto_compact`` optionally evicts
  tombstones past a dead-fraction threshold right after a batch with
  deletes.  Because every query flush applies the mutations queued
  before it, any search observes exactly the mutations submitted
  before it — and results stay bit-identical to direct
  ``AshIndex.search`` on the equivalently-mutated index.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import QueryPrep
from repro.index.api import AshIndex, IVFBackend

NEG_INF = float("-inf")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Tuning knobs of a :class:`QueryEngine`.

    batch_buckets / k_buckets: ascending padded shapes; values above
    the largest bucket round up to a multiple of it (so shapes stay a
    closed set and traces stay bounded).

    The prep cache is BYTE-bounded (``prep_cache_bytes``, summing the
    numpy footprint of every cached row's projection tuple) so capacity
    planning works in memory terms regardless of query width;
    ``prep_cache_entries`` is an optional additional row-count bound
    (None = rows limited by bytes only).  Setting either to 0 disables
    the cache.
    """

    batch_buckets: Tuple[int, ...] = (8, 32, 128)
    k_buckets: Tuple[int, ...] = (10, 100)
    max_pending: int = 1024  # queue bound, in query rows
    max_wait_s: float = 0.002  # flush-on-timeout age
    prep_cache_bytes: int = 64 << 20  # LRU byte budget; 0 disables
    prep_cache_entries: Optional[int] = None  # extra row bound; 0 disables
    # mutation backlog bound, in staged add rows + queued delete ids:
    # past it the batch applies immediately instead of waiting for the
    # next query flush / poll timeout
    max_pending_mutations: int = 4096
    # evict tombstones whenever a mutation batch leaves the index's
    # dead fraction above this (None = never compact automatically)
    auto_compact: Optional[float] = None

    def __post_init__(self):
        if not self.batch_buckets or not self.k_buckets:
            raise ValueError("batch_buckets and k_buckets must be non-empty")
        for name in ("batch_buckets", "k_buckets"):
            v = getattr(self, name)
            if tuple(sorted(v)) != tuple(v) or min(v) < 1:
                raise ValueError(f"{name} must be ascending positive: {v}")
        if self.prep_cache_bytes < 0:
            raise ValueError(
                f"prep_cache_bytes must be >= 0: {self.prep_cache_bytes}"
            )
        if self.prep_cache_entries is not None and self.prep_cache_entries < 0:
            raise ValueError(
                f"prep_cache_entries must be >= 0: {self.prep_cache_entries}"
            )
        if self.max_pending_mutations < 1:
            raise ValueError(
                f"max_pending_mutations must be >= 1: "
                f"{self.max_pending_mutations}"
            )
        if self.auto_compact is not None and not (
            0.0 <= self.auto_compact < 1.0
        ):
            raise ValueError(
                f"auto_compact must be in [0, 1): {self.auto_compact}"
            )

    @property
    def prep_cache_enabled(self) -> bool:
        return self.prep_cache_bytes > 0 and self.prep_cache_entries != 0


def _bucketize(buckets: Tuple[int, ...], n: int) -> int:
    """Smallest bucket >= n, else n rounded up to a multiple of the
    largest bucket (keeps the shape set closed for any request size)."""
    for b in buckets:
        if n <= b:
            return b
    big = buckets[-1]
    return ((n + big - 1) // big) * big


def _pad_rows(rows: np.ndarray, bucket: int) -> np.ndarray:
    """Zero-pad (n, D) query rows up to the bucket's row count."""
    if bucket <= rows.shape[0]:
        return rows
    pad = np.zeros((bucket - rows.shape[0], rows.shape[1]), np.float32)
    return np.concatenate([rows, pad], axis=0)


@dataclasses.dataclass
class RequestStats:
    """Per-request serving stats, filled when the request completes."""

    queue_wait_s: float = 0.0  # submit -> scoring start
    latency_s: float = 0.0  # submit -> result scattered back
    batch_rows: int = 0  # real rows in the fused call
    bucket_rows: int = 0  # padded rows (the trace shape)
    scoring_us: float = 0.0  # fused scoring call, whole bucket
    prep_hits: int = 0  # this request's rows found in the prep cache
    prep_misses: int = 0
    # "size" | "timeout" | "manual" | "pressure" | "barrier" (the group
    # was flushed because a mutation arrived for its index)
    flush_reason: str = ""


@dataclasses.dataclass
class EngineStats:
    """Aggregate counters across the engine lifetime."""

    requests: int = 0
    batches: int = 0  # fused scoring calls
    batched_rows: int = 0  # real rows served
    padded_rows: int = 0  # zero rows added by bucketing
    prep_hits: int = 0
    prep_misses: int = 0
    mutations: int = 0  # submit_add/submit_delete calls
    added_rows: int = 0  # rows ingested via applied mutation batches
    deleted_rows: int = 0  # rows tombstoned via applied batches
    mutation_batches: int = 0  # batched apply steps (the amortized op)
    compactions: int = 0  # auto_compact evictions triggered
    flushes: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {
            "size": 0, "timeout": 0, "manual": 0, "pressure": 0,
            "barrier": 0,
        }
    )
    # distinct (index, bucket, k, params) combinations that ran — the
    # engine-side upper bound on jit traces of the scoring call
    compiled_buckets: set = dataclasses.field(default_factory=set)

    def snapshot(self) -> Dict[str, Any]:
        fill = self.batched_rows / max(
            1, self.batched_rows + self.padded_rows
        )
        looked_up = self.prep_hits + self.prep_misses
        return {
            "requests": self.requests,
            "batches": self.batches,
            "rows": self.batched_rows,
            "bucket_fill": round(fill, 3),
            "prep_hits": self.prep_hits,
            "prep_misses": self.prep_misses,
            "prep_hit_rate": round(self.prep_hits / max(1, looked_up), 3),
            "mutations": self.mutations,
            "added_rows": self.added_rows,
            "deleted_rows": self.deleted_rows,
            "mutation_batches": self.mutation_batches,
            "compactions": self.compactions,
            "flushes": dict(self.flushes),
            "unique_buckets": len(self.compiled_buckets),
        }


class Ticket:
    """Handle for a submitted request; resolves when its group flushes."""

    def __init__(self, engine: "QueryEngine", group: tuple, k: int,
                 n_rows: int):
        self._engine = engine
        self._group = group
        self.k = k
        self.n_rows = n_rows
        self.stats = RequestStats()
        self._result: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._error: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        return self._result is not None or self._error is not None

    def result(self) -> Tuple[np.ndarray, np.ndarray]:
        """(scores, ids), numpy arrays, each (n_rows, k).  Flushes the
        request's group if it is still queued.  If the fused call for
        this request's batch failed (e.g. an option the backend
        rejects), re-raises that error here as well as at the flush
        site."""
        if not self.done:
            self._engine._flush_group(self._group, "manual")
        if self._error is not None:
            raise RuntimeError(
                "request failed during its batch's fused scoring call"
            ) from self._error
        assert self._result is not None
        return self._result


class MutationTicket:
    """Handle for a submitted mutation; resolves when its index's
    queued mutation batch is applied (next query flush of that index,
    ``flush()``, an aged ``poll()``, backlog overflow — or this
    ticket's ``result()``)."""

    def __init__(self, engine: "QueryEngine", index_name: str,
                 kind: str, n_rows: int):
        self._engine = engine
        self._index = index_name
        self.kind = kind  # "add" | "delete"
        self.n_rows = n_rows  # rows staged (add) / ids requested (delete)
        self.t_enqueue = time.perf_counter()
        self.apply_s = 0.0  # duration of the whole batched apply step
        self.ids: Optional[np.ndarray] = None  # adds: assigned user ids
        self._result: Optional[Any] = None
        self._error: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        return self._result is not None or self._error is not None

    def result(self):
        """Adds: the (n,) int64 user ids the rows received (also on
        ``.ids`` immediately after submit).  Deletes: the number of
        rows newly tombstoned.  Applies the index's pending mutation
        batch if it is still queued; re-raises the batch's error if
        the apply failed."""
        if not self.done:
            self._engine._apply_mutations(self._index)
        if self._error is not None:
            raise RuntimeError(
                "mutation failed during its batched apply step"
            ) from self._error
        return self._result


@dataclasses.dataclass
class _Request:
    queries: np.ndarray  # (m, D) float32, contiguous
    k: int
    ticket: Ticket
    t_enqueue: float


class QueryEngine:
    """See the module docstring.  Single-threaded core: ``submit`` /
    ``poll`` / ``flush`` are meant to be driven by one serving loop
    (async transport is a ROADMAP follow-up)."""

    def __init__(
        self,
        indexes: Union[AshIndex, Dict[str, AshIndex], None] = None,
        config: Optional[EngineConfig] = None,
        **overrides,
    ):
        if config is None:
            config = EngineConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self._indexes: Dict[str, AshIndex] = {}
        self._pending: "OrderedDict[tuple, list[_Request]]" = OrderedDict()
        self._pending_rows = 0
        self._prep_cache: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._prep_cache_nbytes = 0
        # queued mutations, per index: add tickets (rows already staged
        # on the AshIndex), delete id lists, and the oldest submission
        # time (drives the poll() age check)
        self._add_tickets: Dict[str, list] = {}
        self._pending_deletes: Dict[str, list] = {}
        self._mutation_t0: Dict[str, float] = {}
        self.stats = EngineStats()
        if isinstance(indexes, AshIndex):
            self.register("default", indexes)
        elif indexes:
            for name, idx in indexes.items():
                self.register(name, idx)

    # -- registry -----------------------------------------------------

    def register(self, name: str, index: AshIndex) -> "QueryEngine":
        """Route ``submit(..., index=name)`` to ``index``.  Re-binding a
        name drops its cached preps (a new index means a new model) and
        first applies any queued mutations against the OLD binding —
        their rows are already staged on that index, so erroring the
        tickets would strand rows that the old index still ingests on
        its next ``apply_pending``.  An apply failure lands on the
        mutation tickets (re-raised by their ``result()``), never here.
        """
        if name in self._indexes:
            self._try_flush(self._apply_mutations, name)
            self.invalidate_prep_cache(name)
        self._indexes[name] = index
        return self

    def index(self, name: str = "default") -> AshIndex:
        return self._indexes[name]

    @property
    def index_names(self) -> Tuple[str, ...]:
        return tuple(self._indexes)

    def invalidate_prep_cache(self, name: Optional[str] = None) -> None:
        if name is None:
            self._prep_cache.clear()
            self._prep_cache_nbytes = 0
            return
        for key in [k for k in self._prep_cache if k[0] == name]:
            self._prep_cache_nbytes -= self._entry_nbytes(
                self._prep_cache.pop(key)
            )

    @property
    def prep_cache_bytes(self) -> int:
        """Current byte footprint of the prep LRU (for capacity
        planning against ``EngineConfig.prep_cache_bytes``)."""
        return self._prep_cache_nbytes

    # -- request intake -----------------------------------------------

    def submit(
        self,
        queries,
        k: int = 10,
        *,
        index: str = "default",
        nprobe: Optional[int] = None,
        rerank: int = 0,
        **opts,
    ) -> Ticket:
        """Queue a request; returns a :class:`Ticket`.  May flush (this
        group on size, any group on timeout or queue pressure)."""
        if index not in self._indexes:
            raise KeyError(
                f"unknown index {index!r}; registered: {self.index_names}"
            )
        idx = self._indexes[index]
        q = np.ascontiguousarray(np.asarray(queries), dtype=np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2:
            raise ValueError(f"queries must be (m, D) or (D,): {q.shape}")
        dim = idx.model.landmarks.shape[1]
        if q.shape[1] != dim:
            # reject here: a mismatched row would join the group and
            # blow up mid-flush, taking unrelated requests with it
            raise ValueError(
                f"query dim {q.shape[1]} != index {index!r} dim {dim}"
            )
        if k < 1:
            raise ValueError(f"k must be >= 1: {k}")
        backend = idx.backend
        if backend != "ivf":
            nprobe = None  # only IVF routes coarsely; don't split groups
        else:
            # normalize to the effective value (default applied, clamped
            # to the invlist count) so nprobe=None, the explicit default
            # and any over-large value share one group/bucket/trace
            nprobe = IVFBackend.resolve_nprobe(idx._state, nprobe)
        # rerank requests must reproduce the direct path's shortlist of
        # max(rerank, k) candidates, so that size is part of the group
        # key and _run_batch clamps k_run to it.  Requests with
        # rerank >= k all share one group (shortlist == rerank); a
        # request with rerank < k gets its own (shortlist == its k) —
        # mixed-k groups there cannot share a fused call bit-identically.
        shortlist = max(rerank, k) if rerank else None
        group = (index, nprobe, rerank, shortlist,
                 tuple(sorted(opts.items())))

        # bounded queue: free space by serving, never by dropping
        if (
            self._pending_rows + q.shape[0] > self.config.max_pending
            and self._pending_rows > 0
        ):
            self._try_flush(self._flush_all, "pressure")

        ticket = Ticket(self, group, k, q.shape[0])
        self._pending.setdefault(group, []).append(
            _Request(q, k, ticket, time.perf_counter())
        )
        self._pending_rows += q.shape[0]
        self.stats.requests += 1

        if (
            self._group_rows(group) >= self.config.batch_buckets[-1]
            or self._pending_rows > self.config.max_pending
        ):
            # bucket fillable, or a single request alone exceeds the
            # queue bound: serve now rather than sit past max_pending
            self._try_flush(self._flush_group, group, "size")
        else:
            self._try_flush(self.poll)
        return ticket

    def search(self, queries, k: int = 10, **kw):
        """Synchronous convenience: submit + resolve immediately.
        (scores, ids) numpy arrays, each (m, k)."""
        return self.submit(queries, k, **kw).result()

    # -- mutation intake ----------------------------------------------

    def submit_add(self, rows, *, index: str = "default") -> MutationTicket:
        """Queue rows for batched ingestion; returns a
        :class:`MutationTicket` whose ``.ids`` already holds the user
        ids the rows will carry (assigned now, in submission order).

        Barriers the index first: queued query groups for it flush
        (they were submitted before this mutation and must see the
        pre-mutation state).  The rows stage host-side and the
        expensive apply (one IVF re-sort / sharded re-placement for
        the WHOLE batch) is deferred to the next query flush of this
        index, ``flush()``, an aged ``poll()``, or backlog overflow.
        """
        idx = self._require_index(index)
        q = np.ascontiguousarray(np.asarray(rows), dtype=np.float32)
        if q.ndim == 1:
            q = q[None, :]
        dim = idx.model.landmarks.shape[1]
        if q.ndim != 2 or q.shape[1] != dim:
            raise ValueError(
                f"add rows must be (n, {dim}) for index {index!r}: "
                f"got {q.shape}"
            )
        self._barrier(index)
        ticket = MutationTicket(self, index, "add", q.shape[0])
        ticket.ids = idx.stage_add(q)
        self._add_tickets.setdefault(index, []).append(ticket)
        self._mutation_t0.setdefault(index, ticket.t_enqueue)
        self.stats.mutations += 1
        self._maybe_apply(index)
        return ticket

    def submit_delete(self, ids, *, index: str = "default") -> MutationTicket:
        """Queue a tombstone delete by user id; the ticket resolves to
        the number of rows newly removed (unknown / already-deleted
        ids are ignored).  Same barrier/batching semantics as
        :meth:`submit_add`; deletes never pay a re-sort at all — only
        an eventual ``compact()`` does."""
        idx = self._require_index(index)
        del_ids = np.asarray(ids).reshape(-1).astype(np.int64)
        self._barrier(index)
        ticket = MutationTicket(self, index, "delete", int(del_ids.size))
        self._pending_deletes.setdefault(index, []).append(
            (del_ids, ticket)
        )
        self._mutation_t0.setdefault(index, ticket.t_enqueue)
        self.stats.mutations += 1
        self._maybe_apply(index)
        return ticket

    def _require_index(self, index: str) -> AshIndex:
        if index not in self._indexes:
            raise KeyError(
                f"unknown index {index!r}; registered: {self.index_names}"
            )
        return self._indexes[index]

    def _barrier(self, name: str) -> None:
        """Flush every queued query group of ``name`` (reason
        "barrier") so queries submitted before a mutation never see
        post-mutation state.  Errors stay on the affected query
        tickets, exactly like submit-triggered flushes."""
        for group in [g for g in self._pending if g[0] == name]:
            self._try_flush(self._flush_group, group, "barrier")

    def _mutation_backlog(self, name: str) -> int:
        return self._indexes[name].pending_rows + sum(
            int(d.size) for d, _ in self._pending_deletes.get(name, ())
        )

    def _maybe_apply(self, name: str) -> None:
        if self._mutation_backlog(name) >= self.config.max_pending_mutations:
            self._try_flush(self._apply_mutations, name)

    def _apply_mutations(self, name: str) -> int:
        """Apply the index's queued mutation batch: ONE backend add for
        every staged row, then the queued deletes (order-equivalent to
        FIFO — delete targets are ids, which adds never disturb), then
        an optional auto-compaction.  Returns rows added + removed."""
        idx = self._indexes.get(name)
        if idx is None:
            return 0
        adds = self._add_tickets.pop(name, [])
        dels = self._pending_deletes.pop(name, [])
        self._mutation_t0.pop(name, None)
        if not adds and not dels and idx.pending_rows == 0:
            return 0
        t0 = time.perf_counter()
        try:
            applied = idx.apply_pending()
            removed = 0
            for del_ids, ticket in dels:
                ticket._result = idx.delete(del_ids)
                removed += ticket._result
        except Exception as e:
            for ticket in adds + [t for _, t in dels]:
                if not ticket.done:
                    ticket._error = e
            raise
        for ticket in adds:
            ticket._result = ticket.ids
        if (
            dels
            and self.config.auto_compact is not None
            and idx.dead_fraction > self.config.auto_compact
        ):
            n_before = idx.n
            idx.compact(self.config.auto_compact)
            if idx.n != n_before:
                self.stats.compactions += 1
        dt = time.perf_counter() - t0
        for ticket in adds + [t for _, t in dels]:
            ticket.apply_s = dt
        self.stats.mutation_batches += 1
        self.stats.added_rows += applied
        self.stats.deleted_rows += removed
        return applied + removed

    # -- flushing -----------------------------------------------------

    def poll(self) -> int:
        """Flush groups whose oldest request exceeded ``max_wait_s``
        and apply mutation batches older than it.  Call this from the
        serving loop's idle path.  Returns the number of requests
        completed (mutations resolve their own tickets)."""
        now = time.perf_counter()
        done = 0
        for group in list(self._pending):
            reqs = self._pending.get(group)
            if reqs and now - reqs[0].t_enqueue >= self.config.max_wait_s:
                done += self._flush_group(group, "timeout")
        for name, t0 in list(self._mutation_t0.items()):
            if now - t0 >= self.config.max_wait_s:
                self._apply_mutations(name)
        return done

    def flush(self) -> int:
        """Serve everything queued, now — query groups AND mutation
        batches.  Returns requests completed; an empty flush is a
        no-op returning 0."""
        done = self._flush_all("manual")
        for name in list(self._mutation_t0):
            self._apply_mutations(name)
        return done

    def _flush_all(self, reason: str) -> int:
        done = 0
        for group in list(self._pending):
            done += self._flush_group(group, reason)
        return done

    @staticmethod
    def _try_flush(fn, *args) -> None:
        """Run a flush triggered from inside ``submit`` without letting
        its errors escape: the caller must always receive its Ticket,
        and a failing fused call (possibly an unrelated group's) already
        resolved every affected ticket with the error — delivered when
        that ticket's ``result()`` is called."""
        try:
            fn(*args)
        except Exception:
            pass

    @property
    def pending_requests(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def _group_rows(self, group: tuple) -> int:
        return sum(
            r.queries.shape[0] for r in self._pending.get(group, ())
        )

    def _flush_group(self, group: tuple, reason: str) -> int:
        if group in self._pending:
            # every queued query of this index was submitted AFTER the
            # mutations still pending for it (each mutation submission
            # barrier-flushed the older queries before staging), so
            # applying the backlog here makes the batch observe exactly
            # the mutations submitted before it — including during a
            # barrier flush, where the NEWEST mutation is not queued
            # yet and therefore (correctly) not applied.
            self._apply_mutations(group[0])
        reqs = self._pending.pop(group, None)
        if not reqs:
            return 0
        self._pending_rows -= sum(r.queries.shape[0] for r in reqs)
        self.stats.flushes[reason] += 1
        # chunk FIFO so no batch exceeds the largest bucket (a single
        # oversized request still rides alone, padded to a multiple)
        big = self.config.batch_buckets[-1]
        chunks: list[list[_Request]] = [[]]
        rows = 0
        for r in reqs:
            m = r.queries.shape[0]
            if chunks[-1] and rows + m > big:
                chunks.append([])
                rows = 0
            chunks[-1].append(r)
            rows += m
        for i, chunk in enumerate(chunks):
            try:
                self._run_batch(group, chunk, reason)
            except Exception as e:
                # the failed chunk's tickets carry the error already
                # (_run_batch); later chunks were popped off the queue
                # too, so resolve them with it as well — no request may
                # end up neither served nor errored
                for later in chunks[i + 1:]:
                    for r in later:
                        r.ticket._error = e
                raise
        return len(reqs)

    # -- the fused scoring call ---------------------------------------

    def _run_batch(
        self, group: tuple, reqs: "list[_Request]", reason: str
    ) -> None:
        name, nprobe, rerank, shortlist, opts = group
        idx = self._indexes[name]
        try:
            rows = np.concatenate([r.queries for r in reqs], axis=0)
            n_real = rows.shape[0]
            bucket = _bucketize(self.config.batch_buckets, n_real)
            rows = _pad_rows(rows, bucket)
            k_max = max(r.k for r in reqs)
            k_run = min(
                _bucketize(self.config.k_buckets, k_max), idx.n
            )
            if shortlist is not None:
                # rerank: the backend's shortlist is max(rerank, k_run);
                # the direct path's is max(rerank, k).  Every request in
                # this group shares shortlist == max(rerank, its k)
                # >= k_max (the group key guarantees it), so clamping
                # k_run keeps the fused call's shortlist — hence its
                # rerank candidates and results — bit-identical to
                # per-request search.
                k_run = min(k_run, shortlist)

            prep, hit_rows = self._prep_for(name, idx, rows, n_real)
            t_score = time.perf_counter()  # after prep/hash: the stat
            scores, ids = jax.block_until_ready(  # is the fused call
                idx.search_prepped(
                    prep, k=k_run, nprobe=nprobe, rerank=rerank,
                    **dict(opts),
                )
            )
        except Exception as e:
            # resolve every ticket with the error (a later result()
            # re-raises it) before surfacing at the flush site — an
            # explicit flush()/poll(); submit-triggered flushes swallow
            # it (_try_flush) so the caller still gets its Ticket
            for r in reqs:
                r.ticket._error = e
            raise
        scoring_us = (time.perf_counter() - t_score) * 1e6
        scores = np.asarray(scores)
        ids = np.asarray(ids)

        self.stats.batches += 1
        self.stats.batched_rows += n_real
        self.stats.padded_rows += bucket - n_real
        self.stats.compiled_buckets.add(
            (name, idx.backend, bucket, k_run, nprobe, rerank, opts)
        )

        offset = 0
        for r in reqs:
            m = r.queries.shape[0]
            s = scores[offset:offset + m]
            i = ids[offset:offset + m]
            if r.k <= k_run:  # top-k prefix of the bucket's top-k_run
                s, i = s[:, : r.k], i[:, : r.k]
            else:  # k > n: pad out with the missing-candidate sentinel
                pad = r.k - k_run
                s = np.concatenate(
                    [s, np.full((m, pad), NEG_INF, s.dtype)], axis=1
                )
                i = np.concatenate(
                    [i, np.full((m, pad), -1, i.dtype)], axis=1
                )
            st = r.ticket.stats
            st.queue_wait_s = t_score - r.t_enqueue
            st.latency_s = time.perf_counter() - r.t_enqueue
            st.batch_rows = n_real
            st.bucket_rows = bucket
            st.scoring_us = scoring_us
            st.prep_hits = int(hit_rows[offset:offset + m].sum())
            st.prep_misses = m - st.prep_hits
            st.flush_reason = reason
            r.ticket._result = (s, i)
            offset += m

    # -- prep cache ---------------------------------------------------

    def _prep_for(
        self, name: str, idx: AshIndex, rows: np.ndarray, n_real: int
    ) -> Tuple[QueryPrep, np.ndarray]:
        """QueryPrep for the padded bucket ``rows``, reusing cached
        per-row projections.  Returns (prep, per-row hit flags for the
        real rows)."""
        bucket = rows.shape[0]
        hit_rows = np.zeros(n_real, dtype=bool)
        if not self.config.prep_cache_enabled:
            self.stats.prep_misses += n_real
            return idx.prepare(jnp.asarray(rows)), hit_rows

        keys = [
            (name, hashlib.blake2b(rows[i].tobytes(),
                                   digest_size=16).digest())
            for i in range(bucket)
        ]
        row_preps: list = [None] * bucket
        miss = []
        for i, key in enumerate(keys):
            cached = self._prep_cache.get(key)
            if cached is not None:
                self._prep_cache.move_to_end(key)
                row_preps[i] = cached
                if i < n_real:
                    hit_rows[i] = True
            else:
                miss.append(i)
        self.stats.prep_hits += int(hit_rows.sum())
        self.stats.prep_misses += n_real - int(hit_rows.sum())

        if not miss:
            return self._stack_prep(row_preps), hit_rows
        if len(miss) == bucket:
            # cold bucket: one prepare over the padded rows, no restack
            # (only real rows are cached — pad rows recur only while
            # buckets run underfilled and would waste LRU capacity)
            prep = jax.block_until_ready(idx.prepare(jnp.asarray(rows)))
            self._cache_prep_rows(keys, prep, range(n_real))
            return prep, hit_rows
        # warm bucket: prepare only the misses (padded to a bucket shape
        # so prepare traces stay bounded), then merge with cached rows
        mb = _bucketize(self.config.batch_buckets, len(miss))
        miss_rows = _pad_rows(rows[miss], mb)
        mp = jax.block_until_ready(idx.prepare(jnp.asarray(miss_rows)))
        mp_np = tuple(np.asarray(a) for a in
                      (mp.q, mp.q_proj, mp.ip_q_landmarks, mp.q_sq_norm))
        for j, i in enumerate(miss):
            row_preps[i] = tuple(a[j] for a in mp_np)
        for i in miss:
            if i < n_real:
                self._cache_put(keys[i], row_preps[i])
        self._evict()
        return self._stack_prep(row_preps), hit_rows

    def _cache_prep_rows(self, keys, prep: QueryPrep, idxs) -> None:
        arrs = tuple(np.asarray(a) for a in
                     (prep.q, prep.q_proj, prep.ip_q_landmarks,
                      prep.q_sq_norm))
        for i in idxs:
            self._cache_put(keys[i], tuple(a[i] for a in arrs))
        self._evict()

    @staticmethod
    def _entry_nbytes(entry: tuple) -> int:
        return sum(int(a.nbytes) for a in entry)

    def _cache_put(self, key: tuple, entry: tuple) -> None:
        old = self._prep_cache.pop(key, None)
        if old is not None:
            self._prep_cache_nbytes -= self._entry_nbytes(old)
        self._prep_cache[key] = entry
        self._prep_cache_nbytes += self._entry_nbytes(entry)

    def _evict(self) -> None:
        cfg = self.config
        while self._prep_cache and (
            self._prep_cache_nbytes > cfg.prep_cache_bytes
            or (cfg.prep_cache_entries is not None
                and len(self._prep_cache) > cfg.prep_cache_entries)
        ):
            _, entry = self._prep_cache.popitem(last=False)
            self._prep_cache_nbytes -= self._entry_nbytes(entry)

    @staticmethod
    def _stack_prep(row_preps) -> QueryPrep:
        q, q_proj, ipl, qsq = (
            jnp.asarray(np.stack([r[f] for r in row_preps]))
            for f in range(4)
        )
        return QueryPrep(
            q=q, q_proj=q_proj, ip_q_landmarks=ipl, q_sq_norm=qsq
        )
