"""Serving integrations of the ASH technique."""
from repro.serving import engine, retrieval
from repro.serving.engine import (
    EngineConfig, MutationTicket, QueryEngine, Ticket,
)

__all__ = [
    "engine", "retrieval", "EngineConfig", "MutationTicket",
    "QueryEngine", "Ticket",
]
