"""Serving integrations of the ASH technique."""
from repro.serving import retrieval

__all__ = ["retrieval"]
