"""Serving integrations of the ASH technique."""
from repro.serving import compactor, engine, frontend, retrieval, wal
from repro.serving.compactor import BackgroundCompactor
from repro.serving.engine import (
    EngineConfig, MutationTicket, QueryEngine, Ticket,
)
from repro.serving.frontend import (
    FrontendClosed, FrontendConfig, ServingFrontend,
)
from repro.serving.wal import (
    DurableIndex, RecoveryReport, WriteAheadLog,
)

__all__ = [
    "compactor", "engine", "frontend", "retrieval", "wal",
    "BackgroundCompactor", "DurableIndex", "EngineConfig",
    "FrontendClosed", "FrontendConfig", "MutationTicket",
    "QueryEngine", "RecoveryReport", "ServingFrontend", "Ticket",
    "WriteAheadLog",
]
