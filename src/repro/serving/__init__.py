"""Serving integrations of the ASH technique."""
from repro.serving import compactor, engine, frontend, retrieval
from repro.serving.compactor import BackgroundCompactor
from repro.serving.engine import (
    EngineConfig, MutationTicket, QueryEngine, Ticket,
)
from repro.serving.frontend import (
    FrontendClosed, FrontendConfig, ServingFrontend,
)

__all__ = [
    "compactor", "engine", "frontend", "retrieval",
    "BackgroundCompactor", "EngineConfig", "FrontendClosed",
    "FrontendConfig", "MutationTicket", "QueryEngine",
    "ServingFrontend", "Ticket",
]
