"""Mutation write-ahead log + durable index wrapper.

Every ``submit_add``/``submit_delete`` the engine acknowledges lives
only in process memory until someone saves — so a crash silently
loses acknowledged work, and the paper's "attractive for real-world
deployment" pitch dies at the first SIGKILL.  This module closes the
gap with the classic recipe:

* :class:`WriteAheadLog` — checksummed append-only record log.  The
  engine appends every mutation batch *before* its tickets resolve,
  so an acknowledged mutation is always reconstructible.
* :class:`DurableIndex` — an :class:`~repro.index.api.AshIndex` plus
  its log directory: atomic checkpoints (``ckpt-<seqno>`` dirs written
  via the index's crash-safe :meth:`~repro.index.api.AshIndex.save`),
  and :meth:`DurableIndex.open` recovery — newest valid checkpoint,
  torn WAL tail truncated, surviving records replayed idempotently
  past the checkpoint's high-water mark.

Record framing (little-endian)::

    magic 'AWAL' | kind u8 | seqno u64 | payload_len u32 | crc32 u32
    | payload

The crc covers kind+seqno+len+payload, so a flipped bit anywhere in a
record is detected; a short read at the tail is a *torn* record.  Both
end replay at the last intact prefix — which is exactly the durable
set.  Seqnos are assigned contiguously from 1; a checkpoint's manifest
stores the last seqno it contains (``wal_seqno``), and replay skips
records at or below it, making recovery idempotent.

Payloads:

* ``add``    — ``n u32 | dim u32 | ids int64[n] | rows f32[n, dim]``
  (the rows AND the ids they were acknowledged under: replay must
  reproduce id assignment bit-for-bit).
* ``delete`` — ``n u32 | ids int64[n]``.
* ``marker`` — UTF-8 text (compaction/checkpoint breadcrumbs; replay
  ignores them).

fsync policy (``always`` / ``interval`` / ``off``) trades ack latency
against the durability horizon: ``always`` fsyncs every append (an
acknowledged mutation survives power loss), ``interval`` bounds the
loss window to ``fsync_interval_s``, ``off`` leaves it to the OS.
All three ``flush()`` every append, so a mere *process* crash never
loses acknowledged work under any policy.

The log is segmented (``wal-<startseq>.log``).  A checkpoint rotates
to a fresh segment under the mutation barrier (cheap), writes the
checkpoint off-lock, then drops every segment whose records are all
covered — the log stays bounded without stalling serving.
"""
from __future__ import annotations

import contextlib
import copy
import dataclasses
import json
import os
import pathlib
import shutil
import struct
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.index.api import AshIndex, CorruptIndexError
from repro.testing import faults

_MAGIC = b"AWAL"
_HEADER = struct.Struct("<4sBQII")  # magic, kind, seqno, len, crc32
_ADD_HEAD = struct.Struct("<II")  # n, dim
_DEL_HEAD = struct.Struct("<I")  # n

KIND_ADD = 1
KIND_DELETE = 2
KIND_MARKER = 3

_FAULT_APPEND = faults.point("wal.append", torn=True)
_FAULT_FSYNC = faults.point("wal.fsync")
_FAULT_ROTATE = faults.point("wal.rotate")
_FAULT_CKPT_BEGIN = faults.point("ckpt.begin")
_FAULT_CKPT_GC = faults.point("ckpt.gc")

_FSYNC_POLICIES = ("always", "interval", "off")


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One decoded log record."""

    seqno: int
    kind: int  # KIND_ADD | KIND_DELETE | KIND_MARKER
    rows: Optional[np.ndarray] = None  # adds: (n, dim) float32
    ids: Optional[np.ndarray] = None  # adds/deletes: (n,) int64
    text: str = ""  # markers


def _encode_record(kind: int, seqno: int, payload: bytes) -> bytes:
    crc = zlib.crc32(
        struct.pack("<BQI", kind, seqno, len(payload)) + payload
    )
    return _HEADER.pack(_MAGIC, kind, seqno, len(payload), crc) + payload


def _decode_payload(kind: int, seqno: int, payload: bytes) -> WalRecord:
    if kind == KIND_ADD:
        n, dim = _ADD_HEAD.unpack_from(payload)
        off = _ADD_HEAD.size
        ids = np.frombuffer(payload, np.int64, n, off).copy()
        rows = np.frombuffer(
            payload, np.float32, n * dim, off + 8 * n
        ).reshape(n, dim).copy()
        return WalRecord(seqno, kind, rows=rows, ids=ids)
    if kind == KIND_DELETE:
        (n,) = _DEL_HEAD.unpack_from(payload)
        ids = np.frombuffer(payload, np.int64, n, _DEL_HEAD.size).copy()
        return WalRecord(seqno, kind, ids=ids)
    return WalRecord(seqno, kind, text=payload.decode("utf-8", "replace"))


def _scan_segment(
    data: bytes, path: pathlib.Path
) -> Tuple[List[WalRecord], int]:
    """Parse one segment's bytes into (records, valid_end): the byte
    offset of the last record that passed framing + crc.  Anything
    past ``valid_end`` is a torn or corrupt tail."""
    records: List[WalRecord] = []
    off = 0
    while True:
        if off + _HEADER.size > len(data):
            return records, off
        magic, kind, seqno, plen, crc = _HEADER.unpack_from(data, off)
        if magic != _MAGIC:
            return records, off
        end = off + _HEADER.size + plen
        if end > len(data):
            return records, off  # torn payload
        payload = data[off + _HEADER.size:end]
        want = zlib.crc32(
            struct.pack("<BQI", kind, seqno, plen) + payload
        )
        if want != crc:
            return records, off
        try:
            records.append(_decode_payload(kind, seqno, payload))
        except Exception:
            return records, off  # framed but undecodable: treat as torn
        off = end


def _segment_start(path: pathlib.Path) -> int:
    return int(path.stem.split("-", 1)[1])


class WriteAheadLog:
    """Append side of the log.  Thread-compatible: appends are assumed
    to be serialized by the caller (the engine holds the per-index
    mutation barrier around every append), rotation included."""

    def __init__(
        self,
        directory,
        *,
        fsync: str = "interval",
        fsync_interval_s: float = 0.05,
        start_seqno: int = 0,
    ):
        if fsync not in _FSYNC_POLICIES:
            raise ValueError(
                f"fsync must be one of {_FSYNC_POLICIES}: {fsync!r}"
            )
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        self.fsync_interval_s = fsync_interval_s
        self._seqno = int(start_seqno)
        self._last_fsync = time.perf_counter()
        self._appends = 0
        self._appended_bytes = 0
        self._fsyncs = 0
        self._rotations = 0
        self._f = None
        self._open_segment()

    # -- segments -----------------------------------------------------

    def _open_segment(self) -> None:
        self._seg_path = self.dir / f"wal-{self._seqno + 1:020d}.log"
        self._f = open(self._seg_path, "ab")

    def rotate(self) -> None:
        """Close the active segment and start a fresh one at the next
        seqno (the checkpoint hook; cheap enough for the barrier)."""
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        faults.fire(_FAULT_ROTATE)
        self._open_segment()
        # The new segment's directory entry must be durable BEFORE any
        # checkpoint GC unlinks the segments it supersedes: a power
        # loss after drop_segments_through with the dirent still in
        # the page cache would leave a log whose covered prefix is
        # gone AND whose active segment never existed.
        _dir_fsync(self.dir)
        self._rotations += 1

    def drop_segments_through(self, seqno: int) -> int:
        """Delete closed segments whose every record is <= ``seqno``
        (i.e. covered by a checkpoint).  Returns segments removed."""
        segs = sorted(
            p for p in self.dir.glob("wal-*.log") if p != self._seg_path
        )
        starts = [_segment_start(p) for p in segs]
        # segment i spans [starts[i], next start - 1]; the active
        # segment starts at self._active_start()
        bounds = starts[1:] + [_segment_start(self._seg_path)]
        dropped = 0
        for path, nxt in zip(segs, bounds):
            if nxt - 1 <= seqno:
                path.unlink(missing_ok=True)
                dropped += 1
        if dropped:
            _dir_fsync(self.dir)
        return dropped

    def segments(self) -> Tuple[pathlib.Path, ...]:
        return tuple(sorted(self.dir.glob("wal-*.log")))

    @property
    def nbytes(self) -> int:
        self._f.flush()
        return sum(p.stat().st_size for p in self.segments())

    # -- appends ------------------------------------------------------

    @property
    def last_seqno(self) -> int:
        return self._seqno

    def append_add(self, rows, ids) -> int:
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        ids = np.ascontiguousarray(ids, dtype=np.int64)
        if rows.ndim != 2 or ids.shape != (rows.shape[0],):
            raise ValueError(
                f"add record needs (n, dim) rows + (n,) ids: "
                f"{rows.shape} / {ids.shape}"
            )
        payload = (
            _ADD_HEAD.pack(rows.shape[0], rows.shape[1])
            + ids.tobytes()
            + rows.tobytes()
        )
        return self._append(KIND_ADD, payload)

    def append_delete(self, ids) -> int:
        ids = np.ascontiguousarray(
            np.asarray(ids).reshape(-1), dtype=np.int64
        )
        return self._append(
            KIND_DELETE, _DEL_HEAD.pack(ids.size) + ids.tobytes()
        )

    def append_marker(self, text: str) -> int:
        return self._append(KIND_MARKER, text.encode("utf-8"))

    def _append(self, kind: int, payload: bytes) -> int:
        seq = self._seqno + 1
        record = _encode_record(kind, seq, payload)
        cut = faults.fire(_FAULT_APPEND, size=len(record))
        if cut is not None:
            # injected torn write: the prefix reaches the OS, then the
            # process "dies" — recovery must truncate it
            self._f.write(record[:cut])
            self._f.flush()
            raise faults.SimulatedCrash(
                f"torn WAL append at seqno {seq} ({cut}/{len(record)}B)"
            )
        self._f.write(record)
        self._f.flush()  # past the process: a crash can't unwrite it
        self._seqno = seq
        self._appends += 1
        self._appended_bytes += len(record)
        if self.fsync == "always":
            self._do_fsync()
        elif self.fsync == "interval":
            now = time.perf_counter()
            if now - self._last_fsync >= self.fsync_interval_s:
                self._do_fsync()
        return seq

    def _do_fsync(self) -> None:
        faults.fire(_FAULT_FSYNC)
        os.fsync(self._f.fileno())
        self._fsyncs += 1
        self._last_fsync = time.perf_counter()

    def sync(self) -> None:
        """Force an fsync regardless of policy."""
        self._f.flush()
        self._do_fsync()

    def close(self) -> None:
        if self._f is not None and not self._f.closed:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()

    def stats(self) -> Dict[str, Any]:
        return {
            "last_seqno": self._seqno,
            "appends": self._appends,
            "appended_bytes": self._appended_bytes,
            "fsyncs": self._fsyncs,
            "rotations": self._rotations,
            "segments": len(self.segments()),
            "fsync": self.fsync,
        }


def _dir_fsync(path: pathlib.Path) -> None:
    try:
        fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def read_log(
    directory, *, truncate: bool = False
) -> Tuple[List[WalRecord], int]:
    """Read every intact record under ``directory`` in seqno order;
    returns (records, torn_bytes).  The durable set is a *prefix*:
    reading stops at the first torn/corrupt record, and later segments
    are not replayed (they would leave a seqno gap).  With
    ``truncate=True`` the torn tail is cut off on disk and later
    segments deleted, so the next append cycle starts clean."""
    d = pathlib.Path(directory)
    records: List[WalRecord] = []
    torn = 0
    clean = True
    for path in sorted(d.glob("wal-*.log")):
        data = path.read_bytes()
        if not clean:
            torn += len(data)
            if truncate:
                path.unlink(missing_ok=True)
            continue
        recs, valid_end = _scan_segment(data, path)
        records.extend(recs)
        if valid_end != len(data):
            clean = False
            torn += len(data) - valid_end
            if truncate:
                with open(path, "r+b") as f:
                    f.truncate(valid_end)
                    f.flush()
                    os.fsync(f.fileno())
    for i in range(1, len(records)):
        if records[i].seqno != records[i - 1].seqno + 1:
            raise CorruptIndexError(
                d,
                f"WAL seqno gap: {records[i - 1].seqno} -> "
                f"{records[i].seqno}",
            )
    return records, torn


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What :meth:`DurableIndex.open` found and did."""

    checkpoint_path: str
    checkpoint_seqno: int  # WAL high-water mark the checkpoint covers
    last_seqno: int  # durable high-water mark after replay
    replayed_adds: int = 0
    replayed_deletes: int = 0
    replayed_rows: int = 0  # rows added + tombstoned by replay
    skipped_stale: int = 0  # records <= checkpoint_seqno (idempotence)
    torn_bytes: int = 0  # truncated off the WAL tail
    discarded_checkpoints: int = 0  # corrupt ckpts skipped over

    def describe(self) -> str:
        return (
            f"checkpoint seq={self.checkpoint_seqno} "
            f"replayed={self.replayed_adds} adds/"
            f"{self.replayed_deletes} dels "
            f"({self.replayed_rows} rows, {self.skipped_stale} stale) "
            f"torn_bytes={self.torn_bytes} last_seq={self.last_seqno}"
        )


class DurableIndex:
    """An :class:`AshIndex` bound to a durability directory::

        path/
          ckpt-<seqno>/   atomic checkpoints (arrays.npz + manifest)
          wal/            segmented mutation log

    Attach to a :class:`~repro.serving.engine.QueryEngine` via
    ``engine.attach_durability(durable)`` — the apply path then logs
    every mutation batch before its tickets resolve.  After any crash,
    :meth:`open` restores exactly the acknowledged state.
    """

    def __init__(
        self,
        index: AshIndex,
        path,
        wal: WriteAheadLog,
        report: Optional[RecoveryReport] = None,
    ):
        self.index = index
        self.path = pathlib.Path(path)
        self.wal = wal
        self.report = report
        self._checkpoints = 0
        self._checkpoint_seqno = (
            0 if report is None else report.checkpoint_seqno
        )
        self._lock = threading.Lock()  # checkpoint vs checkpoint

    # -- construction -------------------------------------------------

    @classmethod
    def create(
        cls,
        index: AshIndex,
        path,
        *,
        fsync: str = "interval",
        fsync_interval_s: float = 0.05,
    ) -> "DurableIndex":
        """Start durability for ``index`` at ``path`` (must not hold a
        checkpoint already): writes checkpoint 0 and opens the log."""
        p = pathlib.Path(path)
        if any(p.glob("ckpt-*")):
            raise FileExistsError(
                f"{p} already holds checkpoints; use DurableIndex.open"
            )
        p.mkdir(parents=True, exist_ok=True)
        wal = WriteAheadLog(
            p / "wal", fsync=fsync, fsync_interval_s=fsync_interval_s,
            start_seqno=0,
        )
        durable = cls(index, p, wal)
        durable.checkpoint()
        return durable

    @staticmethod
    def exists(path) -> bool:
        """True if ``path`` holds at least one checkpoint dir."""
        return any(pathlib.Path(path).glob("ckpt-*"))

    @classmethod
    def open(
        cls,
        path,
        *,
        fsync: str = "interval",
        fsync_interval_s: float = 0.05,
        index_opts: Optional[dict] = None,
    ) -> "DurableIndex":
        """Recover: load the newest checkpoint that passes integrity
        checks, truncate any torn WAL tail, replay surviving records
        past the checkpoint's high-water mark, and reopen the log for
        appending.  The recovered index is bit-identical to a fresh
        build over the serially-replayed acknowledged mutations."""
        p = pathlib.Path(path)
        candidates = sorted(p.glob("ckpt-*"), reverse=True)
        if not candidates:
            raise CorruptIndexError(p, "no checkpoints found")
        index = None
        discarded = 0
        last_err: Optional[Exception] = None
        for ckpt in candidates:
            try:
                index = AshIndex.load(ckpt, **(index_opts or {}))
                break
            except CorruptIndexError as e:
                discarded += 1
                last_err = e
        if index is None:
            raise CorruptIndexError(
                p, f"no valid checkpoint among {len(candidates)}: "
                   f"{last_err}"
            )
        hwm = int(
            json.loads((ckpt / "config.json").read_text())
            .get("wal_seqno", 0)
        )
        records, torn = read_log(p / "wal", truncate=True)
        adds = dels = rows = stale = 0
        prev = None
        for rec in records:
            if rec.seqno <= hwm:
                stale += 1
                continue
            if prev is not None and rec.seqno != prev + 1:
                raise CorruptIndexError(
                    p / "wal",
                    f"replay seqno gap: {prev} -> {rec.seqno}",
                )
            if prev is None and rec.seqno != hwm + 1:
                raise CorruptIndexError(
                    p / "wal",
                    f"WAL starts at seqno {rec.seqno}, checkpoint "
                    f"covers through {hwm}",
                )
            prev = rec.seqno
            if rec.kind == KIND_ADD:
                got = index.stage_add(rec.rows)
                if not np.array_equal(got, rec.ids):
                    raise CorruptIndexError(
                        p / "wal",
                        f"replay id mismatch at seqno {rec.seqno}: "
                        f"assigned {got[:4]}.. != logged {rec.ids[:4]}..",
                    )
                index.apply_pending()
                adds += 1
                rows += int(rec.rows.shape[0])
            elif rec.kind == KIND_DELETE:
                rows += index.delete(rec.ids)
                dels += 1
            # markers replay as no-ops
        last = records[-1].seqno if records else hwm
        last = max(last, hwm)
        wal = WriteAheadLog(
            p / "wal", fsync=fsync, fsync_interval_s=fsync_interval_s,
            start_seqno=last,
        )
        report = RecoveryReport(
            checkpoint_path=str(ckpt),
            checkpoint_seqno=hwm,
            last_seqno=last,
            replayed_adds=adds,
            replayed_deletes=dels,
            replayed_rows=rows,
            skipped_stale=stale,
            torn_bytes=torn,
            discarded_checkpoints=discarded,
        )
        return cls(index, p, wal, report)

    # -- the engine-facing logging surface ----------------------------

    def log_add(self, rows, ids) -> int:
        """Append an acknowledged add batch; returns its seqno.  The
        engine calls this under the index's mutation barrier, before
        the batch's tickets fire."""
        return self.wal.append_add(rows, ids)

    def log_delete(self, ids) -> int:
        return self.wal.append_delete(ids)

    def log_marker(self, text: str) -> int:
        return self.wal.append_marker(text)

    # -- checkpointing ------------------------------------------------

    def checkpoint(self, *, barrier=None) -> int:
        """Checkpoint-then-truncate: snapshot the index state and the
        WAL high-water mark (under ``barrier`` if given — pass the
        engine's ``mutation_barrier`` so the pair is consistent),
        rotate the log, write the checkpoint atomically OFF the lock,
        then GC checkpoints and covered segments.  Returns the seqno
        the new checkpoint covers.  Crash-safe at every step: until
        the final rename the old checkpoint + full log win."""
        with self._lock:
            cm = barrier if barrier is not None \
                else contextlib.nullcontext()
            with cm:
                state = copy.copy(self.index._state)
                hwm = self.wal.last_seqno
                self.wal.rotate()
            faults.fire(_FAULT_CKPT_BEGIN)
            ckpt_dir = self.path / f"ckpt-{hwm:020d}"
            if not ckpt_dir.exists():
                # the clone holds state only: staged-but-unlogged rows
                # are NOT durable yet (their tickets haven't fired), so
                # they are excluded and replay of their eventual WAL
                # records reassigns the very same ids
                clone = AshIndex(
                    self.index.backend, self.index.metric, state
                )
                clone.save(ckpt_dir, extra_meta={"wal_seqno": hwm})
            faults.fire(_FAULT_CKPT_GC)
            for d in sorted(self.path.glob("ckpt-*")):
                if d != ckpt_dir:
                    shutil.rmtree(d, ignore_errors=True)
            self.wal.drop_segments_through(hwm)
            self._checkpoints += 1
            self._checkpoint_seqno = hwm
            return hwm

    def close(self) -> None:
        self.wal.close()

    def stats(self) -> Dict[str, Any]:
        s = self.wal.stats()
        s.update(
            checkpoints=self._checkpoints,
            checkpoint_seqno=self._checkpoint_seqno,
        )
        return s
