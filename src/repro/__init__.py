"""repro: production-grade JAX framework reproducing ASH
(Asymmetric Scalar Hashing, Tepper & Willke 2026) with a multi-pod
distributed runtime, assigned-architecture model zoo, and Pallas TPU
kernels for the scoring hot path."""

__version__ = "0.1.0"
