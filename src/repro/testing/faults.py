"""Fault-injection points for the durability stack.

Production code declares *named points* at the instants that matter
for crash recovery (just before a WAL write hits the file, between
the two renames of an atomic save, after mutations are logged but
before they are applied, ...).  Each point is a single call::

    faults.fire("wal.append", size=len(record))

which is a no-op (one dict lookup) unless a test has armed a *plan*::

    with faults.active({"wal.append": faults.Crash(at=2)}):
        ...  # the 2nd WAL append raises SimulatedCrash

Four actions model the failure modes a process actually has:

* :class:`Crash`  — raise :class:`SimulatedCrash` *before* the guarded
  effect happens (power loss at a clean boundary).  The harness then
  abandons every in-memory object and recovers from disk, exactly as
  a killed process would.
* :class:`Torn`   — for points that write a buffer (``fire(...,
  size=n)``): return a byte count < n; the caller writes that prefix,
  flushes it, and raises ``SimulatedCrash`` — a write torn mid-record.
* :class:`Error`  — raise :class:`InjectedError` (an ordinary
  ``Exception``): the failure path that *is* supposed to be caught,
  e.g. a full disk the engine must surface without losing tickets.
* :class:`Delay`  — sleep, then proceed: widens race windows.

``SimulatedCrash`` derives from ``BaseException`` ON PURPOSE: the
serving stack guards many paths with ``except Exception`` (a failing
fused call must not kill the driver), and a real ``kill -9`` does not
care about those guards — neither may the simulated one.

The registry of points is static (module import registers them), so a
test can *enumerate* every point and prove recovery at each:

    for point in faults.points():
        run_crash_recovery_case(point)
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Dict, Iterator, Optional, Tuple


class SimulatedCrash(BaseException):
    """The process died here.  BaseException so production ``except
    Exception`` guards can't absorb it — only the test harness, which
    then recovers from disk, may catch it."""


class InjectedError(RuntimeError):
    """An ordinary injected failure (disk full, EIO, ...) that the
    production error paths are expected to handle."""


@dataclasses.dataclass(frozen=True)
class Point:
    name: str
    torn: bool = False  # point passes size= and honours a torn cut


@dataclasses.dataclass(frozen=True)
class Crash:
    at: int = 1  # fire on the at-th hit since install
    repeat: bool = False  # also fire on every later hit


@dataclasses.dataclass(frozen=True)
class Torn:
    at: int = 1
    fraction: float = 0.5  # prefix of the write that reaches disk
    repeat: bool = False


@dataclasses.dataclass(frozen=True)
class Error:
    at: int = 1
    repeat: bool = False


@dataclasses.dataclass(frozen=True)
class Delay:
    at: int = 1
    seconds: float = 0.001
    repeat: bool = False


_lock = threading.Lock()
_points: Dict[str, Point] = {}
_plan: Dict[str, object] = {}
_hits: Dict[str, int] = {}


def point(name: str, *, torn: bool = False) -> str:
    """Register a fault point (idempotent); returns ``name`` so call
    sites can bind it to a module constant."""
    with _lock:
        _points[name] = Point(name, torn=torn)
    return name


def points(prefix: str = "") -> Tuple[Point, ...]:
    """Every registered point (optionally filtered by name prefix),
    sorted by name — the enumeration tests iterate."""
    with _lock:
        return tuple(
            p for n, p in sorted(_points.items())
            if n.startswith(prefix)
        )


def install(plan: Dict[str, object]) -> None:
    """Arm ``plan`` ({point name: action}); replaces any previous plan
    and resets hit counters.  Unknown point names are a test bug and
    raise ``ValueError``."""
    with _lock:
        unknown = set(plan) - set(_points)
        if unknown:
            raise ValueError(
                f"unknown fault points {sorted(unknown)}; "
                f"registered: {sorted(_points)}"
            )
        _plan.clear()
        _plan.update(plan)
        _hits.clear()


def reset() -> None:
    """Disarm every fault; ``fire`` returns to its no-op fast path."""
    with _lock:
        _plan.clear()
        _hits.clear()


def hits(name: str) -> int:
    """How many times ``name`` fired since the last install."""
    with _lock:
        return _hits.get(name, 0)


@contextlib.contextmanager
def active(plan: Dict[str, object]) -> Iterator[None]:
    """``with faults.active({...}):`` — install on entry, reset on
    exit (including on the SimulatedCrash the plan raises)."""
    install(plan)
    try:
        yield
    finally:
        reset()


def fire(name: str, *, size: Optional[int] = None) -> Optional[int]:
    """The production-side hook.  Returns None (proceed normally) or,
    for an armed :class:`Torn` at a ``size=``-passing point, the byte
    prefix the caller must write before raising ``SimulatedCrash``.
    """
    if not _plan:  # fast path: benign race, worst case one lock trip
        return None
    with _lock:
        action = _plan.get(name)
        if action is None:
            return None
        _hits[name] = n = _hits.get(name, 0) + 1
    if n < action.at or (n > action.at and not action.repeat):
        return None
    if isinstance(action, Crash):
        raise SimulatedCrash(f"injected crash at {name} (hit {n})")
    if isinstance(action, Torn):
        if size is None or size <= 1:
            # point can't tear a write: degrade to a clean crash
            raise SimulatedCrash(
                f"injected crash at {name} (hit {n}, torn unsupported)"
            )
        return max(1, min(size - 1, int(size * action.fraction)))
    if isinstance(action, Error):
        raise InjectedError(f"injected error at {name} (hit {n})")
    if isinstance(action, Delay):
        time.sleep(action.seconds)
        return None
    raise TypeError(f"unknown fault action {action!r}")
