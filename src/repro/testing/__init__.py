"""Test-support machinery importable from production code.

Only :mod:`repro.testing.faults` lives here: zero-cost fault-injection
points the durability stack compiles in, armed exclusively by tests.
"""
from repro.testing import faults

__all__ = ["faults"]
