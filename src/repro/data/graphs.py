"""Graph generation + the neighbor sampler (required by minibatch_lg).

The sampler is the real thing: fanout-limited k-hop uniform neighbor
sampling over a CSR adjacency, host-side numpy (the standard production
split: sampling on CPU workers, model on accelerator), emitting
static-shape padded subgraphs so the jitted train step never recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # (N+1,)
    indices: np.ndarray  # (E,)
    feats: Optional[np.ndarray] = None  # (N, F)
    positions: Optional[np.ndarray] = None  # (N, 3)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)


def random_graph(
    seed: int, n_nodes: int, avg_degree: int, d_feat: int = 0,
    spatial: bool = True,
) -> CSRGraph:
    """Random sparse graph; positions drawn in a box sized for ~avg_degree
    neighbors within the NequIP cutoff."""
    rng = np.random.RandomState(seed)
    n_edges = n_nodes * avg_degree
    src = rng.randint(0, n_nodes, n_edges)
    dst = (src + 1 + rng.randint(0, n_nodes - 1, n_edges)) % n_nodes
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    feats = (
        rng.randn(n_nodes, d_feat).astype(np.float32) if d_feat else None
    )
    positions = None
    if spatial:
        box = (n_nodes / max(avg_degree, 1)) ** (1 / 3) * 4.0
        positions = (rng.rand(n_nodes, 3) * box).astype(np.float32)
    return CSRGraph(
        indptr=indptr, indices=dst.astype(np.int64), feats=feats,
        positions=positions,
    )


def neighbor_sample(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    rng: np.random.RandomState,
):
    """k-hop fanout sampling. Returns a padded subgraph dict:
       nodes (pad_n,), edge_src/edge_dst (pad_e,) LOCAL indices,
       node_mask, edge_mask, n_seeds.
    Static pad sizes derive from seeds*prod(fanouts)."""
    layers = [seeds]
    edges_src, edges_dst = [], []
    frontier = seeds
    for f in fanouts:
        new_src, new_dst = [], []
        for u in frontier:
            lo, hi = graph.indptr[u], graph.indptr[u + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg)
            picks = graph.indices[
                lo + rng.choice(deg, size=take, replace=False)
            ]
            new_src.extend(picks.tolist())
            new_dst.extend([u] * take)
        frontier = np.unique(np.asarray(new_src, np.int64))
        layers.append(frontier)
        edges_src.extend(new_src)
        edges_dst.extend(new_dst)

    nodes = np.unique(np.concatenate(layers))
    remap = {int(g): i for i, g in enumerate(nodes)}
    e_src = np.asarray([remap[int(s)] for s in edges_src], np.int32)
    e_dst = np.asarray([remap[int(d)] for d in edges_dst], np.int32)

    # static pads
    pad_n = int(len(seeds) * np.prod([f + 1 for f in fanouts]))
    pad_e = int(len(seeds) * np.prod(fanouts) * (1 + sum(fanouts)))
    pad_n = max(pad_n, len(nodes))
    pad_e = max(pad_e, len(e_src))
    node_mask = np.zeros(pad_n, bool)
    node_mask[: len(nodes)] = True
    edge_mask = np.zeros(pad_e, bool)
    edge_mask[: len(e_src)] = True
    nodes_p = np.zeros(pad_n, np.int64)
    nodes_p[: len(nodes)] = nodes
    es = np.zeros(pad_e, np.int32)
    es[: len(e_src)] = e_src
    ed = np.zeros(pad_e, np.int32)
    ed[: len(e_dst)] = e_dst
    return {
        "nodes": nodes_p,
        "edge_src": es,
        "edge_dst": ed,
        "node_mask": node_mask,
        "edge_mask": edge_mask,
        "n_real_nodes": len(nodes),
        "n_seeds": len(seeds),
    }


def batch_small_graphs(
    seed: int, n_graphs: int, nodes_per: int, edges_per: int,
    n_species: int = 16,
):
    """Disjoint-union batching of small molecules -> one big graph dict."""
    rng = np.random.RandomState(seed)
    N = n_graphs * nodes_per
    E = n_graphs * edges_per
    positions = rng.randn(N, 3).astype(np.float32) * 1.5
    species = rng.randint(0, n_species, N).astype(np.int32)
    src = np.zeros(E, np.int32)
    dst = np.zeros(E, np.int32)
    gid = np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per)
    for g in range(n_graphs):
        s = rng.randint(0, nodes_per, edges_per)
        d = (s + 1 + rng.randint(0, nodes_per - 1, edges_per)) % nodes_per
        src[g * edges_per:(g + 1) * edges_per] = s + g * nodes_per
        dst[g * edges_per:(g + 1) * edges_per] = d + g * nodes_per
    return {
        "positions": positions,
        "species": species,
        "edge_src": src,
        "edge_dst": dst,
        "graph_ids": gid,
        "n_graphs": n_graphs,
    }
