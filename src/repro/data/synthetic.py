"""Synthetic data generators for every substrate (offline container: the
paper's embedding datasets are not downloadable — see DESIGN.md §6).

``embedding_dataset`` reproduces the paper's Table-4 non-isotropy
diagnostics: anisotropic covariance (power-law spectrum), non-zero mean,
optional cluster structure — so data-driven vs data-agnostic gaps behave
like they do on real embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def embedding_dataset(
    key: jax.Array,
    n: int,
    D: int,
    *,
    spectrum_pow: float = 0.7,
    mean_shift: float = 0.5,
    n_clusters: int = 8,
    cluster_spread: float = 2.0,
    normalize: bool = False,
) -> jax.Array:
    """(n, D) anisotropic, shifted, clustered 'embedding-like' vectors."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    A = jax.random.normal(k1, (D, D)) * (
        jnp.arange(1, D + 1, dtype=jnp.float32) ** -spectrum_pow
    )[None, :]
    centers = (
        jax.random.normal(k2, (n_clusters, D)) @ A.T * cluster_spread
    )
    assign = jax.random.randint(k3, (n,), 0, n_clusters)
    X = jax.random.normal(k4, (n, D)) @ A.T + centers[assign] + mean_shift
    if normalize:
        X = X / jnp.linalg.norm(X, axis=-1, keepdims=True)
    return X


def isotropy_diagnostics(X: jax.Array, sample: int = 2048) -> dict:
    """The paper's Table-4 statistics: min pairwise cosSim, ||mean||_inf."""
    Xs = X[:sample]
    Xn = Xs / jnp.linalg.norm(Xs, axis=-1, keepdims=True)
    cos = Xn @ Xn.T
    cos = cos - 2.0 * jnp.eye(cos.shape[0])  # exclude self
    mu = jnp.mean(X, axis=0)
    return {
        "min_cos_sim": float(jnp.min(cos + 2.0 * jnp.eye(cos.shape[0]))),
        "mean_inf_norm": float(jnp.max(jnp.abs(mu))),
    }


# ---------------------------------------------------------------------------
# Resumable host-side iterators (checkpointable cursor)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IteratorState:
    seed: int
    step: int = 0

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class TokenStream:
    """Deterministic synthetic LM token stream: batch t is a pure function
    of (seed, t) — restart from a checkpointed cursor is exact."""

    def __init__(self, state: IteratorState, batch: int, seq: int,
                 vocab: int):
        self.state = state
        self.batch, self.seq, self.vocab = batch, seq, vocab

    def next(self) -> dict:
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.state.seed), self.state.step
        )
        # Markov-ish structure so the LM has something learnable:
        # token t+1 = (a * token_t + noise) mod vocab
        k1, k2 = jax.random.split(key)
        start = jax.random.randint(k1, (self.batch, 1), 0, self.vocab)
        steps = jax.random.randint(
            k2, (self.batch, self.seq - 1), 0, 7
        )

        def scan_row(carry, s):
            nxt = (carry * 31 + s) % self.vocab
            return nxt, nxt

        _, rest = jax.lax.scan(
            scan_row, start[:, 0], steps.T
        )
        tokens = jnp.concatenate([start, rest.T], axis=1).astype(jnp.int32)
        self.state.step += 1
        return {"tokens": tokens, "labels": tokens}


class ClickStream:
    """Synthetic CTR batches with a learnable planted rule."""

    def __init__(self, state: IteratorState, batch: int, n_dense: int,
                 n_sparse: int, vocab: int):
        self.state = state
        self.batch, self.n_dense = batch, n_dense
        self.n_sparse, self.vocab = n_sparse, vocab

    def next(self) -> dict:
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.state.seed), self.state.step
        )
        k1, k2, k3 = jax.random.split(key, 3)
        sparse = jax.random.randint(
            k1, (self.batch, self.n_sparse), 0, self.vocab
        )
        dense = jax.random.normal(k2, (self.batch, self.n_dense))
        # planted rule: label depends on parity interactions + dense sum
        score = (
            jnp.sum((sparse % 5 == 0).astype(jnp.float32), axis=-1)
            - 0.5 * jnp.sum(dense, axis=-1) / max(self.n_dense, 1)
        )
        p = jax.nn.sigmoid(score - jnp.mean(score))
        labels = jax.random.bernoulli(k3, p).astype(jnp.float32)
        self.state.step += 1
        return {
            "sparse": sparse.astype(jnp.int32),
            "dense": dense.astype(jnp.float32),
            "labels": labels,
        }


class SequenceStream:
    """SASRec-style user histories with sequential structure."""

    def __init__(self, state: IteratorState, batch: int, seq: int,
                 n_items: int, n_neg: int = 128):
        self.state = state
        self.batch, self.seq = batch, seq
        self.n_items, self.n_neg = n_items, n_neg

    def next(self) -> dict:
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.state.seed), self.state.step
        )
        k1, k2, k3 = jax.random.split(key, 3)
        start = jax.random.randint(k1, (self.batch,), 1, self.n_items)
        drift = jax.random.randint(
            k2, (self.batch, self.seq), 1, 17
        )
        seq = (start[:, None] + jnp.cumsum(drift, axis=1)) % (
            self.n_items - 1
        ) + 1
        labels = jnp.roll(seq, -1, axis=1).at[:, -1].set(0)
        negs = jax.random.randint(k3, (self.n_neg,), 1, self.n_items)
        self.state.step += 1
        return {
            "seq": seq.astype(jnp.int32),
            "labels": labels.astype(jnp.int32),
            "negatives": negs.astype(jnp.int32),
        }
