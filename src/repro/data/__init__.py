"""Synthetic data pipelines (embedding sets, token/click/sequence
streams, graphs + neighbor sampler)."""
from repro.data import synthetic, graphs

__all__ = ["synthetic", "graphs"]
