"""Training substrate: optimizers, compression, trainer, checkpointing."""
from repro.train import optim, compression, trainer, checkpoint

__all__ = ["optim", "compression", "trainer", "checkpoint"]
