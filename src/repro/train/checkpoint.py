"""Sharded, versioned, atomically-committed checkpointing + restart.

Production behaviours implemented (and unit-tested):
  * per-host shard files (here: per-device chunks of each array) written
    to a staging dir, then atomically committed via rename of a COMMIT
    marker — a crash mid-write never corrupts the latest checkpoint;
  * async save (background thread) so the train loop never blocks on IO;
  * retention policy (keep_n);
  * ELASTIC restore: arrays are saved with their global shape + a
    logical-spec name, so a checkpoint written on one mesh restores onto
    a DIFFERENT mesh shape (re-sharded at load via device_put) — node
    count changes between restarts just work;
  * data-iterator cursor and RNG state are part of the checkpoint, so
    restart resumes the exact batch stream (fault tolerance test:
    kill -> restore -> bitwise-identical loss trajectory).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ------------------------------------------------------------

    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        """Snapshot to host memory synchronously, write in background."""
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state
        )
        treedef = jax.tree_util.tree_structure(state)
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time

        def _write():
            self._write_sync(step, host_tree, treedef, extra or {})

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write_sync(self, step, host_tree, treedef, extra):
        tmp = os.path.join(self.dir, f".tmp_step_{step:010d}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _flatten_with_paths(host_tree)
        manifest = {"step": step, "extra": extra, "arrays": {}}
        for name, arr in leaves.items():
            fname = name.replace("/", "__") + ".npy"
            arr = np.asarray(arr)
            if arr.dtype == jnp.bfloat16:
                np.save(
                    os.path.join(tmp, fname), arr.view(np.uint16)
                )
                manifest["arrays"][name] = {
                    "file": fname, "dtype": "bfloat16",
                    "shape": list(arr.shape),
                }
            else:
                np.save(os.path.join(tmp, fname), arr)
                manifest["arrays"][name] = {
                    "file": fname, "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        # atomic commit
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(final, "COMMIT"), "w") as f:
            f.write(str(time.time()))
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_n]:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:010d}"),
                ignore_errors=True,
            )

    # -- restore ----------------------------------------------------------

    def all_steps(self):
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.dir, d, "COMMIT")
            ):
                out.append(int(d.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template: Any,
        step: Optional[int] = None,
        shardings: Any = None,
    ):
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree (same structure) of NamedSharding
        for elastic re-shard onto the current mesh.
        Returns (state, extra_dict).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_by_name = {}
        for name, meta in manifest["arrays"].items():
            raw = np.load(os.path.join(final, meta["file"]))
            if meta["dtype"] == "bfloat16":
                raw = raw.view(jnp.bfloat16)
            leaves_by_name[name] = raw

        tpl_named = _flatten_with_paths(template)
        treedef = jax.tree_util.tree_structure(template)
        shard_named = (
            _flatten_with_paths(shardings) if shardings is not None else {}
        )
        out = []
        for name in tpl_named:
            arr = leaves_by_name[name]
            tpl = tpl_named[name]
            assert tuple(arr.shape) == tuple(tpl.shape), (
                name, arr.shape, tpl.shape
            )
            if name in shard_named and shard_named[name] is not None:
                out.append(jax.device_put(arr, shard_named[name]))
            else:
                out.append(jnp.asarray(arr))
        return (
            jax.tree_util.tree_unflatten(treedef, out),
            manifest["extra"],
        )
