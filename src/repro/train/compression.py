"""Gradient compression for data-parallel all-reduce (beyond-paper).

EDEN [Vargaftik et al. 2022] — one of the paper's baselines — IS a
distributed mean-estimation scheme; here it is wired into training: each
DP worker rotates its gradient block with a seeded structured rotation
(randomized Hadamard), scalar-quantizes to b bits on the Lloyd-Max grid,
all-reduces the small integer payloads, and unrotates.  Error feedback
(residual carried to the next step) keeps the bias bounded.

Since every worker uses the SAME seeded rotation, the all-reduce can sum
quantized payloads directly (dequantize -> psum -> unrotate), which is
how we express it in shard_map.  In pjit-only training we expose
``compress_decompress`` as a gradient transformation whose round-trip
noise equals the communication-compressed path (the collective itself is
inserted by GSPMD); EXPERIMENTS.md discusses the equivalence.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.baselines.eden import lloyd_max_grid_np


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    bits: int = 2
    enabled: bool = False
    error_feedback: bool = True
    block: int = 2048  # rotation block size (power of 2)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _hadamard(x: jax.Array) -> jax.Array:
    """Fast Walsh-Hadamard transform along the last axis (power of 2)."""
    n = x.shape[-1]
    h = 1
    while h < n:
        x = x.reshape(x.shape[:-1] + (n // (2 * h), 2, h))
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.concatenate([a + b, a - b], axis=-1).reshape(
            x.shape[:-3] + (n,)
        )
        h *= 2
    return x / jnp.sqrt(jnp.float32(n))


def _rand_signs(key: jax.Array, n: int) -> jax.Array:
    return jax.random.rademacher(key, (n,), dtype=jnp.float32)


def compress_decompress(
    key: jax.Array, g: jax.Array, cfg: CompressionConfig
) -> jax.Array:
    """EDEN round trip on a flat vector: rotate -> b-bit LM quant -> scale
    -> unrotate.  The wire payload between workers would be the b-bit
    codes + one fp16 scale per block."""
    n = g.shape[0]
    B = cfg.block
    n_pad = ((n + B - 1) // B) * B
    x = jnp.pad(g.astype(jnp.float32), (0, n_pad - n)).reshape(-1, B)
    signs = _rand_signs(key, B)
    y = _hadamard(x * signs[None, :])
    grid = jnp.asarray(lloyd_max_grid_np(cfg.bits))
    # normalize per block to unit coordinate variance
    norm = jnp.linalg.norm(y, axis=-1, keepdims=True)
    yn = y / jnp.maximum(norm, 1e-12) * jnp.sqrt(jnp.float32(B))
    mids = (grid[1:] + grid[:-1]) / 2.0
    codes = jnp.searchsorted(mids, yn)
    deq = grid[codes]
    s = norm[:, 0] / jnp.maximum(
        jnp.linalg.norm(deq, axis=-1), 1e-12
    )
    y_hat = deq * s[:, None]
    x_hat = _hadamard(y_hat) * signs[None, :]
    return x_hat.reshape(-1)[:n].astype(g.dtype)


class EFState(NamedTuple):
    residual: Any  # error-feedback memory, same tree as grads


def ef_init(params) -> EFState:
    return EFState(
        residual=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    )


def compress_tree(
    key: jax.Array, grads, ef: EFState, cfg: CompressionConfig
):
    """Apply EDEN round-trip with error feedback to every leaf."""
    if not cfg.enabled:
        return grads, ef
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = jax.tree_util.tree_flatten(ef.residual)[0]
    out, new_res = [], []
    for i, (g, r) in enumerate(zip(leaves, res_leaves)):
        gi = g.astype(jnp.float32) + (r if cfg.error_feedback else 0.0)
        flat = gi.reshape(-1)
        deq = compress_decompress(
            jax.random.fold_in(key, i), flat, cfg
        ).reshape(g.shape)
        out.append(deq.astype(g.dtype))
        new_res.append(
            (gi - deq) if cfg.error_feedback else jnp.zeros_like(gi)
        )
    return (
        jax.tree_util.tree_unflatten(treedef, out),
        EFState(residual=jax.tree_util.tree_unflatten(treedef, new_res)),
    )
