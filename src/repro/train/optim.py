"""Optimizers (pure JAX): AdamW, Adafactor (factored 2nd moment, for the
1T-parameter MoE where full fp32 Adam states cannot fit HBM), and Muon
(Newton-Schulz orthogonalized momentum — the same polar-factor iteration
the ASH learner uses for its Procrustes step).

API mirrors optax: init(params) -> state;
update(grads, state, params) -> (updates, state). Updates are ADDED.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.learning import newton_schulz


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adafactor | muon
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # memory knobs for the >=100B regime
    moment_dtype: Any = jnp.float32  # bf16 halves optimizer HBM
    # muon
    ns_steps: int = 5
    # warmup/cosine schedule
    warmup_steps: int = 100
    total_steps: int = 10_000


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree
    ), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(cfg: OptConfig, params) -> AdamState:
    z = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(z, params),
        nu=jax.tree_util.tree_map(z, params),
    )


def adamw_update(cfg: OptConfig, grads, state: AdamState, params):
    step = state.step + 1
    lr = lr_at(cfg, step)
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / (1 - cfg.b1 ** step)
        vhat = v32 / (1 - cfg.b2 ** step)
        u = -lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps)
            + cfg.weight_decay * p.astype(jnp.float32)
        )
        return (
            u.astype(p.dtype),
            m32.astype(cfg.moment_dtype),
            v32.astype(cfg.moment_dtype),
        )

    out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
    updates = jax.tree_util.tree_map(lambda t: t[0], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                is_leaf=lambda x: isinstance(x, tuple))
    return updates, AdamState(step=step, mu=mu, nu=nu)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------


class AdafactorState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment (moment_dtype) — optional momentum
    vr: Any  # row statistics
    vc: Any  # col statistics
    v: Any  # full second moment for <2D params


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(cfg: OptConfig, params) -> AdafactorState:
    def zr(p):
        return (
            jnp.zeros(p.shape[:-1], jnp.float32)
            if _factored(p) else jnp.zeros((1,), jnp.float32)
        )

    def zc(p):
        return (
            jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            if _factored(p) else jnp.zeros((1,), jnp.float32)
        )

    def zv(p):
        return (
            jnp.zeros((1,), jnp.float32)
            if _factored(p) else jnp.zeros(p.shape, jnp.float32)
        )

    # b1 == 0 -> momentum-free Adafactor (classic): no first-moment
    # buffers at all, the key memory saving for the 1T-param config.
    if cfg.b1 == 0.0:
        mu = jax.tree_util.tree_map(
            lambda p: jnp.zeros((1,), cfg.moment_dtype), params
        )
    else:
        mu = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params
        )
    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        mu=mu,
        vr=jax.tree_util.tree_map(zr, params),
        vc=jax.tree_util.tree_map(zc, params),
        v=jax.tree_util.tree_map(zv, params),
    )


def adafactor_update(cfg: OptConfig, grads, state: AdafactorState, params):
    step = state.step + 1
    lr = lr_at(cfg, step)
    grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, m, vr, vc, v, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + 1e-30
        if _factored(p):
            vr_n = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
            vc_n = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(
                jnp.mean(vr_n, axis=-1, keepdims=True), 1e-30
            )
            vhat = (
                vr_n[..., None] * vc_n[..., None, :]
                / denom[..., None]
            )
            v_n = v
        else:
            vhat = decay * v + (1 - decay) * g2
            v_n = vhat
            vr_n, vc_n = vr, vc
        u = g32 / jnp.sqrt(vhat + cfg.eps)
        if cfg.b1 == 0.0:
            m32 = m  # dummy (1,) buffer, untouched
            upd32 = u
        else:
            m32 = (cfg.b1 * m.astype(jnp.float32)
                   + (1 - cfg.b1) * u).astype(cfg.moment_dtype)
            upd32 = m32.astype(jnp.float32)
        out = -lr * (upd32 + cfg.weight_decay * p.astype(jnp.float32))
        return (out.astype(p.dtype), m32, vr_n, vc_n, v_n)

    out = jax.tree_util.tree_map(
        upd, grads, state.mu, state.vr, state.vc, state.v, params
    )
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    return pick(0), AdafactorState(
        step=step, mu=pick(1), vr=pick(2), vc=pick(3), v=pick(4)
    )


# ---------------------------------------------------------------------------
# Muon (momentum + Newton-Schulz orthogonalization for 2D params)
# ---------------------------------------------------------------------------


class MuonState(NamedTuple):
    step: jax.Array
    mu: Any


def muon_init(cfg: OptConfig, params) -> MuonState:
    return MuonState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params
        ),
    )


def muon_update(cfg: OptConfig, grads, state: MuonState, params):
    step = state.step + 1
    lr = lr_at(cfg, step)
    grads, _ = clip_by_global_norm(grads, cfg.grad_clip)

    def upd(g, m, p):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + g32
        if p.ndim == 2 and min(p.shape) > 1:
            # polar factor of m32 (== U V^T of its SVD), same shape
            o = newton_schulz(m32.T, steps=cfg.ns_steps)
            o = o * jnp.sqrt(
                jnp.float32(max(p.shape)) / jnp.float32(min(p.shape))
            )
        else:
            o = m32 / (jnp.linalg.norm(m32.reshape(-1)) + 1e-9)
        u = -lr * (o + cfg.weight_decay * p.astype(jnp.float32))
        return u.astype(p.dtype), m32.astype(cfg.moment_dtype)

    out = jax.tree_util.tree_map(upd, grads, state.mu, params)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    return pick(0), MuonState(step=step, mu=pick(1))


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return (
            functools.partial(adamw_init, cfg),
            functools.partial(adamw_update, cfg),
        )
    if cfg.name == "adafactor":
        return (
            functools.partial(adafactor_init, cfg),
            functools.partial(adafactor_update, cfg),
        )
    if cfg.name == "muon":
        return (
            functools.partial(muon_init, cfg),
            functools.partial(muon_update, cfg),
        )
    raise ValueError(cfg.name)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32)
                      + u.astype(jnp.float32)).astype(p.dtype),
        params, updates,
    )
