"""Train-step builder: microbatched (gradient-accumulation) train step
with mixed precision, optional gradient compression, and a TrainState
pytree that checkpoints/restores cleanly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.train import optim as O
from repro.train.compression import (
    CompressionConfig, EFState, compress_tree, ef_init,
)


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    ef_state: Optional[EFState]
    step: jax.Array
    rng: jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: O.OptConfig = O.OptConfig()
    microbatches: int = 1  # gradient-accumulation chunks per step
    compression: CompressionConfig = CompressionConfig()
    grad_accum_dtype: Any = jnp.float32


def init_state(
    key: jax.Array, params, tcfg: TrainConfig
) -> TrainState:
    opt_init, _ = O.make_optimizer(tcfg.opt)
    ef = ef_init(params) if tcfg.compression.enabled else None
    return TrainState(
        params=params,
        opt_state=opt_init(params),
        ef_state=ef,
        step=jnp.zeros((), jnp.int32),
        rng=key,
    )


def make_train_step(
    loss_fn: Callable,  # (params, batch) -> scalar loss
    tcfg: TrainConfig,
    constrain_state=lambda s: s,
    constrain_grads=lambda g: g,
):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 splits the batch along axis 0 of every leaf and
    accumulates gradients with lax.scan (bounds activation memory —
    required for the 1T-param config).  ``constrain_grads`` pins the
    gradient (and grad-accumulator scan carry) sharding to the parameter
    sharding — without it GSPMD may keep full-size gradients live.
    """
    _, opt_update = O.make_optimizer(tcfg.opt)
    k = tcfg.microbatches

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, constrain_grads(grads)

    def train_step(state: TrainState, batch):
        params = state.params
        if k > 1:
            def reshape(x):
                # (B, ...) -> (k, B//k, ...) with microbatches INTERLEAVED
                # (row r of microbatch m = global row r*k + m) so a batch
                # dim sharded over DP keeps every device busy in every
                # microbatch (consecutive-block split would idle shards).
                return x.reshape(
                    (x.shape[0] // k, k) + x.shape[1:]
                ).swapaxes(0, 1)

            micro = jax.tree_util.tree_map(reshape, batch)

            def body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grads_of(params, mb)
                grad_acc = constrain_grads(jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(tcfg.grad_accum_dtype) / k,
                    grad_acc, grads,
                ))
                return (loss_acc + loss / k, grad_acc), None

            zero = constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, tcfg.grad_accum_dtype),
                params,
            ))
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zero), micro
            )
        else:
            loss, grads = grads_of(params, batch)

        ef = state.ef_state
        if tcfg.compression.enabled:
            ck = jax.random.fold_in(state.rng, state.step)
            grads, ef = compress_tree(ck, grads, ef, tcfg.compression)

        updates, opt_state = opt_update(grads, state.opt_state, params)
        params = O.apply_updates(params, updates)
        new_state = TrainState(
            params=params,
            opt_state=opt_state,
            ef_state=ef,
            step=state.step + 1,
            rng=state.rng,
        )
        metrics = {
            "loss": loss,
            "grad_norm": O.global_norm(grads),
            "step": new_state.step,
        }
        return constrain_state(new_state), metrics

    return train_step
